"""MoE dispatch equivalence + RWKV/Mamba recurrence consistency."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ModelConfig
from repro.nn import mamba as mamba_lib
from repro.nn import moe as moe_lib
from repro.nn import rwkv as rwkv_lib


def test_moe_sort_matches_dense(rng):
    cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=16, vocab=32,
                      n_experts=8, top_k=2, d_expert=32, shared_expert_ff=64)
    p, _ = moe_lib.moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(2, 10, 16)).astype(np.float32))
    y_sort, m1 = moe_lib.moe_forward(p, x, cfg, impl="sort")
    y_dense, _ = moe_lib.moe_forward(p, x, cfg, impl="dense")
    np.testing.assert_allclose(y_sort, y_dense, atol=1e-4)
    assert float(m1["moe_lb_loss"]) >= 1.0  # >= 1 by Cauchy-Schwarz at balance


def test_moe_padded_experts(rng):
    cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=16, vocab=32,
                      n_experts=6, n_experts_padded=8, top_k=2, d_expert=32)
    p, _ = moe_lib.moe_init(jax.random.PRNGKey(0), cfg)
    assert p["gate"].shape[0] == 8
    assert p["router"].shape[1] == 6  # router never selects padded experts
    x = jnp.asarray(rng.normal(size=(2, 10, 16)).astype(np.float32))
    y_sort, _ = moe_lib.moe_forward(p, x, cfg, impl="sort")
    y_dense, _ = moe_lib.moe_forward(p, x, cfg, impl="dense")
    np.testing.assert_allclose(y_sort, y_dense, atol=1e-4)


def test_moe_grads_flow(rng):
    cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=16, vocab=32,
                      n_experts=4, top_k=2, d_expert=16)
    p, _ = moe_lib.moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(1, 8, 16)).astype(np.float32))

    def loss(p):
        y, m = moe_lib.moe_forward(p, x, cfg, impl="sort")
        return jnp.sum(y ** 2) + m["moe_lb_loss"]

    g = jax.grad(loss)(p)
    for key in ("gate", "up", "down", "router"):
        assert float(jnp.abs(g[key]).max()) > 0, key


@pytest.fixture
def rwkv_cfg():
    return ModelConfig(name="r", family="rwkv6", n_layers=1, d_model=32,
                       vocab=32, d_ff=64, rwkv_head_dim=16, lora_rank=16)


def test_rwkv_time_mix_step_consistency(rng, rwkv_cfg):
    cfg = rwkv_cfg
    p, _ = rwkv_lib.time_mix_init(jax.random.PRNGKey(1), cfg)
    B, S, d = 2, 9, 32
    x = jnp.asarray(rng.normal(size=(B, S, d)).astype(np.float32) * 0.5)
    st0 = rwkv_lib.RWKVState.zeros(B, 2, 16, d, jnp.float32)
    y_full, _ = rwkv_lib.time_mix_forward(p, x, cfg, st0)
    st = st0
    ys = []
    for t in range(S):
        y_t, st = rwkv_lib.time_mix_step(p, x[:, t : t + 1], cfg, st)
        ys.append(y_t)
    got = jnp.concatenate(ys, 1)
    scale = max(np.abs(np.asarray(y_full)).max(), 1.0)
    assert np.abs(np.asarray(got - y_full)).max() / scale < 1e-3


def test_mamba_step_consistency(rng):
    cfg = ModelConfig(name="m", family="hybrid", n_layers=1, d_model=32,
                      vocab=32, ssm_state=16, ssm_head_dim=16, ssm_groups=2,
                      ssm_expand=2, ssm_conv=4)
    p, _ = mamba_lib.mamba_init(jax.random.PRNGKey(2), cfg)
    B, S = 2, 9
    x = jnp.asarray(rng.normal(size=(B, S, 32)).astype(np.float32) * 0.5)
    conv_dim = 2 * 32 + 2 * 2 * 16
    st0 = mamba_lib.MambaState.zeros(B, 4, conv_dim, 4, 16, 16, jnp.float32)
    y_full, _ = mamba_lib.mamba_forward(p, x, cfg, st0)
    st = st0
    ys = []
    for t in range(S):
        y_t, st = mamba_lib.mamba_step(p, x[:, t : t + 1], cfg, st)
        ys.append(y_t)
    got = jnp.concatenate(ys, 1)
    scale = max(np.abs(np.asarray(y_full)).max(), 1.0)
    assert np.abs(np.asarray(got - y_full)).max() / scale < 1e-3
