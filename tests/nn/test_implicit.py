"""Implicit (DEQ) layer: forward = solve, backward = adjoint, trains end to end.

The backward pass is verified against central finite differences in f64 dense
arithmetic — the acceptance criterion for the custom_vjp ↔ Transpose mapping.
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.models import deq as deq_lib
from repro.nn.implicit import make_implicit_solve
from repro.solvers.common import Stop
from repro.sparse.gallery import convection_diffusion_2d

TIGHT = Stop(max_iters=400, reduction_factor=1e-10)


def _fixture(n_side=6, peclet=2.0, seed=0):
    indptr, indices, values, shape = convection_diffusion_2d(n_side, peclet=peclet)
    rng = np.random.default_rng(seed)
    vals = jnp.asarray(
        values + 0.01 * rng.standard_normal(values.shape).astype(np.float32)
    )
    n = shape[0]
    b = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    rows = np.repeat(np.arange(n), np.diff(indptr))
    return indptr, indices, shape, rows, vals, b


def _dense(rows, indices, n, values):
    d = np.zeros((n, n), np.float64)
    d[rows, indices] = values
    return d


def test_forward_is_the_solve():
    indptr, indices, shape, rows, vals, b = _fixture()
    solve = make_implicit_solve(indptr, indices, shape, stop=TIGHT)
    x = np.asarray(solve(vals, b))
    xd = np.linalg.solve(_dense(rows, indices, shape[0], np.asarray(vals)),
                         np.asarray(b, np.float64))
    np.testing.assert_allclose(x, xd, rtol=1e-4, atol=1e-5)


def test_gradients_match_finite_differences():
    indptr, indices, shape, rows, vals, b = _fixture()
    n = shape[0]
    solve = make_implicit_solve(indptr, indices, shape, stop=TIGHT)
    w = jnp.asarray(
        np.random.default_rng(1).standard_normal(n).astype(np.float32)
    )

    def loss(vals, b):
        x = solve(vals, b)
        return jnp.sum(w * x) + 0.5 * jnp.sum(x * x)

    gv, gb = jax.grad(loss, argnums=(0, 1))(vals, b)

    def loss_np(va, bb):
        x = np.linalg.solve(_dense(rows, indices, n, va), bb)
        return float(np.sum(np.asarray(w, np.float64) * x)
                     + 0.5 * np.sum(x * x))

    v64 = np.asarray(vals, np.float64)
    b64 = np.asarray(b, np.float64)
    eps = 1e-6
    for t in (0, 7, len(v64) // 2, len(v64) - 1):
        vp, vm = v64.copy(), v64.copy()
        vp[t] += eps
        vm[t] -= eps
        fd = (loss_np(vp, b64) - loss_np(vm, b64)) / (2 * eps)
        assert abs(fd - float(gv[t])) <= 1e-3 * max(1.0, abs(fd)), (
            f"d/dvalues[{t}]: fd {fd} vs vjp {float(gv[t])}"
        )
    for i in (0, n // 2, n - 1):
        bp, bm = b64.copy(), b64.copy()
        bp[i] += eps
        bm[i] -= eps
        fd = (loss_np(v64, bp) - loss_np(v64, bm)) / (2 * eps)
        assert abs(fd - float(gb[i])) <= 1e-3 * max(1.0, abs(fd)), (
            f"d/db[{i}]: fd {fd} vs vjp {float(gb[i])}"
        )


def test_solve_composes_with_jit_and_vmap():
    indptr, indices, shape, rows, vals, b = _fixture()
    solve = make_implicit_solve(indptr, indices, shape, stop=TIGHT)
    x = solve(vals, b)
    batched = jax.jit(jax.vmap(lambda bb: solve(vals, bb)))
    out = batched(jnp.stack([b, 2 * b, -b]))
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(x),
                               rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(out[1]), 2 * np.asarray(x),
                               rtol=1e-3, atol=1e-4)


def test_rectangular_pattern_rejected():
    indptr = np.array([0, 1, 2])
    indices = np.array([0, 1])
    try:
        make_implicit_solve(indptr, indices, (2, 3))
    except ValueError as e:
        assert "square" in str(e)
    else:
        raise AssertionError("non-square pattern accepted")


def test_deq_smoke_training_reduces_loss():
    """End-to-end: the DEQ model (GMRES forward, adjoint-Transpose backward)
    must strictly reduce the teacher-student loss — the DEQ-GATE criterion."""
    from repro.launch.train import train_deq

    assert train_deq(steps=12, batch=8, log_every=6)


def test_deq_forward_batch_shapes():
    cfg = deq_lib.DeqConfig(n_side=6)
    params = deq_lib.init_deq(jax.random.PRNGKey(0), cfg)
    u = jnp.ones((5, cfg.d_in), jnp.float32)
    y = deq_forward_out = deq_lib.deq_forward(params, u, cfg)
    assert deq_forward_out.shape == (5,)
    assert np.all(np.isfinite(np.asarray(y)))
