"""Regression-gate compare rules, incl. bound-normalized frac pins."""

from benchmarks.check_regression import FRAC_TOLERANCE, compare, regressions


def _snap(pinned, bound=None):
    snap = {"schema": "repro-bench/1", "pinned": pinned, "records": []}
    if bound is not None:
        snap["records"] = [{"kind": "spmv", "bound_gbs": bound}]
    return snap


def test_count_and_bool_pins_exact():
    prev = _snap({"launches": 2, "converged": True})
    cur = _snap({"launches": 3, "converged": False})
    bad = {r["key"] for r in regressions(compare(prev, cur))}
    assert bad == {"launches", "converged"}
    ok = compare(prev, _snap({"launches": 2, "converged": True}))
    assert not regressions(ok)


def test_missing_pin_fails():
    rows = compare(_snap({"iters": 5}), _snap({}))
    assert regressions(rows)[0]["threshold"] == "must exist"


def test_ratio_pin_ten_percent_band():
    prev = _snap({"iter_ratio": 40.0})
    assert not regressions(compare(prev, _snap({"iter_ratio": 36.5})))
    assert regressions(compare(prev, _snap({"iter_ratio": 35.0})))


def test_frac_pin_normalized_by_stream_bound():
    """The same achieved GB/s under a 4x higher measured bound must pass:
    the gate compares bandwidth, not the machine-relative fraction."""
    prev = _snap({"frac_spmv_csr_x": 0.0400}, bound=6.0)
    # achieved = 0.04 * 6 = 0.24 GB/s; same bandwidth at bound 24 -> 0.01
    cur = _snap({"frac_spmv_csr_x": 0.0100}, bound=24.0)
    assert not regressions(compare(prev, cur))
    # a real bandwidth collapse past the wide band still fails
    floor = 0.01 * (1.0 - FRAC_TOLERANCE)
    worse = _snap({"frac_spmv_csr_x": floor * 0.9}, bound=24.0)
    assert regressions(compare(prev, worse))


def test_frac_pin_without_bounds_falls_back_to_ratio_rule():
    prev = _snap({"frac_spmv_csr_x": 0.04})
    assert regressions(compare(prev, _snap({"frac_spmv_csr_x": 0.03})))
    assert not regressions(compare(prev, _snap({"frac_spmv_csr_x": 0.039})))
