"""Regression-gate compare rules, incl. bound-normalized frac pins and
gap-tolerant previous-snapshot discovery."""

import json

from benchmarks.check_regression import (
    FRAC_TOLERANCE,
    compare,
    find_previous,
    main,
    regressions,
)


def _snap(pinned, bound=None):
    snap = {"schema": "repro-bench/1", "pinned": pinned, "records": []}
    if bound is not None:
        snap["records"] = [{"kind": "spmv", "bound_gbs": bound}]
    return snap


def test_count_and_bool_pins_exact():
    prev = _snap({"launches": 2, "converged": True})
    cur = _snap({"launches": 3, "converged": False})
    bad = {r["key"] for r in regressions(compare(prev, cur))}
    assert bad == {"launches", "converged"}
    ok = compare(prev, _snap({"launches": 2, "converged": True}))
    assert not regressions(ok)


def test_missing_pin_fails():
    rows = compare(_snap({"iters": 5}), _snap({}))
    assert regressions(rows)[0]["threshold"] == "must exist"


def test_ratio_pin_ten_percent_band():
    prev = _snap({"iter_ratio": 40.0})
    assert not regressions(compare(prev, _snap({"iter_ratio": 36.5})))
    assert regressions(compare(prev, _snap({"iter_ratio": 35.0})))


def test_frac_pin_normalized_by_stream_bound():
    """The same achieved GB/s under a 4x higher measured bound must pass:
    the gate compares bandwidth, not the machine-relative fraction."""
    prev = _snap({"frac_spmv_csr_x": 0.0400}, bound=6.0)
    # achieved = 0.04 * 6 = 0.24 GB/s; same bandwidth at bound 24 -> 0.01
    cur = _snap({"frac_spmv_csr_x": 0.0100}, bound=24.0)
    assert not regressions(compare(prev, cur))
    # a real bandwidth collapse past the wide band still fails
    floor = 0.01 * (1.0 - FRAC_TOLERANCE)
    worse = _snap({"frac_spmv_csr_x": floor * 0.9}, bound=24.0)
    assert regressions(compare(prev, worse))


def test_frac_pin_without_bounds_falls_back_to_ratio_rule():
    prev = _snap({"frac_spmv_csr_x": 0.04})
    assert regressions(compare(prev, _snap({"frac_spmv_csr_x": 0.03})))
    assert not regressions(compare(prev, _snap({"frac_spmv_csr_x": 0.039})))


def _write_snap(path, pinned):
    path.write_text(json.dumps(dict(_snap(pinned), schema="repro-bench/1")))


def test_find_previous_skips_gaps(tmp_path):
    """With only pr6 and pr9 committed, pr10 must diff against pr9 — the
    *latest prior by PR number* — not a nonexistent pr9==N-1 assumption,
    and never a future snapshot."""
    for n in (6, 9, 12):
        _write_snap(tmp_path / f"BENCH_pr{n}.json", {"iters": n})
    cur = tmp_path / "BENCH_pr10.json"
    _write_snap(cur, {"iters": 10})
    prev = find_previous(str(cur))
    assert prev is not None and prev.endswith("BENCH_pr9.json")


def test_find_previous_none_when_first(tmp_path):
    cur = tmp_path / "BENCH_pr3.json"
    _write_snap(cur, {"iters": 1})
    assert find_previous(str(cur)) is None


def test_main_gap_case_end_to_end(tmp_path, capsys):
    """Full gate run over a gap: pr10 vs {pr6, pr9} passes against pr9's
    pins and fails against a (hypothetical) regression from pr9, proving
    the comparison really used pr9 and not pr6."""
    _write_snap(tmp_path / "BENCH_pr6.json", {"launches": 99})
    _write_snap(tmp_path / "BENCH_pr9.json", {"launches": 2})
    cur = tmp_path / "BENCH_pr10.json"

    _write_snap(cur, {"launches": 2})
    assert main(["--current", str(cur)]) == 0
    out = capsys.readouterr().out
    assert "REGRESSION-GATE: PASS" in out and "BENCH_pr9.json" in out

    # 3 launches would pass vs pr6's 99 — it must FAIL because pr9 is the base
    _write_snap(cur, {"launches": 3})
    assert main(["--current", str(cur)]) == 1
    out = capsys.readouterr().out
    assert "REGRESSION-GATE: FAIL" in out and "BENCH_pr9.json" in out
