"""Optimizer, data pipeline, checkpointing, compression, fault-tolerance."""

import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hyp_compat import given, st

from repro.checkpoint import CheckpointManager
from repro.data import (
    DataConfig,
    DataIterator,
    entropy_floor,
    global_step_batch,
    shard_batch_np,
)
from repro.optim import (
    adamw,
    clip_by_global_norm,
    compress_tree,
    constant_schedule,
    decompress_tree,
    init_error_state,
    quantize_int8,
    dequantize_int8,
    warmup_cosine_schedule,
)
from repro.runtime import PreemptionHandler, StragglerMonitor, run_with_restarts


# -- optimizer ---------------------------------------------------------------------

def test_adamw_converges_quadratic():
    opt = adamw(warmup_cosine_schedule(0.1, 10, 200), weight_decay=0.0)
    params = {"w": jnp.ones(4) * 3.0}
    state = opt.init(params)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum((p["w"] - 1.0) ** 2))(params)
        params, state, _ = opt.update(params, g, state)
    np.testing.assert_allclose(params["w"], 1.0, atol=1e-2)


def test_adamw_weight_decay_pulls_to_zero():
    opt = adamw(constant_schedule(0.05), weight_decay=1.0, clip_norm=None)
    params = {"w": jnp.ones(4)}
    state = opt.init(params)
    for _ in range(100):
        g = {"w": jnp.zeros(4)}
        params, state, _ = opt.update(params, g, state)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_clip_by_global_norm():
    tree = {"a": jnp.ones(4) * 10.0}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    np.testing.assert_allclose(float(norm), 20.0)
    np.testing.assert_allclose(
        float(jnp.linalg.norm(clipped["a"])), 1.0, rtol=1e-5
    )


def test_schedule_shapes():
    s = warmup_cosine_schedule(1.0, 10, 100, final_frac=0.1)
    assert float(s(jnp.int32(0))) == 0.0
    np.testing.assert_allclose(float(s(jnp.int32(10))), 1.0, rtol=1e-5)
    np.testing.assert_allclose(float(s(jnp.int32(100))), 0.1, rtol=1e-4)


# -- data --------------------------------------------------------------------------

def test_data_determinism_and_sharding():
    cfg = DataConfig(vocab=64, seq_len=8, global_batch=4, num_shards=2, seed=5)
    b1, b2 = global_step_batch(cfg, 3), global_step_batch(cfg, 3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    s0, s1 = shard_batch_np(cfg, 3, 0), shard_batch_np(cfg, 3, 1)
    assert not np.array_equal(s0["tokens"], s1["tokens"])
    np.testing.assert_array_equal(
        np.concatenate([s0["tokens"], s1["tokens"]]), b1["tokens"]
    )
    # next-token labels
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_data_resume_state():
    cfg = DataConfig(vocab=64, seq_len=8, global_batch=2, seed=1)
    it = DataIterator(cfg)
    next(it)
    st_ = it.state()
    it2 = DataIterator(cfg)
    it2.restore(st_)
    np.testing.assert_array_equal(next(it)["tokens"], next(it2)["tokens"])


@given(step=st.integers(0, 1000), shard=st.integers(0, 7))
def test_data_pure_function_property(step, shard):
    cfg = DataConfig(vocab=128, seq_len=16, global_batch=16, num_shards=8, seed=9)
    a = shard_batch_np(cfg, step, shard)
    b = shard_batch_np(cfg, step, shard)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].min() >= 0 and a["tokens"].max() < 128


def test_entropy_floor_positive():
    cfg = DataConfig(vocab=64, seq_len=8, global_batch=2, seed=1)
    assert 0.5 < entropy_floor(cfg) < np.log(5) + 1e-6


def test_stub_embeddings_mode():
    cfg = DataConfig(vocab=64, seq_len=8, global_batch=2, seed=1, stub_embed_dim=32)
    b = global_step_batch(cfg, 0)
    assert "embeds" in b and "tokens" not in b
    assert b["embeds"].shape == (2, 8, 32)


# -- checkpoint ----------------------------------------------------------------------

def test_checkpoint_roundtrip_keepk_atomic():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep_k=2)
        tree = {"a": jnp.arange(6.0), "b": {"c": jnp.ones((2, 3), jnp.int32)}}
        for s in (1, 2, 3):
            mgr.save(s, tree, metadata={"step": s})
        mgr.wait()
        assert mgr.all_steps() == [2, 3]
        proto = jax.tree_util.tree_map(jnp.zeros_like, tree)
        got, meta = mgr.restore(target=proto)
        assert meta["step"] == 3
        np.testing.assert_array_equal(got["a"], tree["a"])
        np.testing.assert_array_equal(got["b"]["c"], tree["b"]["c"])
        # a stale .tmp dir is garbage-collected on init
        os.makedirs(os.path.join(d, "step_00000009.tmp"))
        CheckpointManager(d)
        assert not os.path.exists(os.path.join(d, "step_00000009.tmp"))


def test_checkpoint_restores_dataclass_pytrees():
    from repro.optim import adamw, constant_schedule

    opt = adamw(constant_schedule(1e-3))
    params = {"w": jnp.ones((3, 2))}
    state = opt.init(params)
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, {"params": params, "opt": state}, block=True)
        proto = {"params": jax.tree_util.tree_map(jnp.zeros_like, params),
                 "opt": jax.tree_util.tree_map(jnp.zeros_like, state)}
        got, _ = mgr.restore(target=proto)
        np.testing.assert_array_equal(got["params"]["w"], params["w"])
        assert int(got["opt"].step) == 0


# -- compression ----------------------------------------------------------------------

def test_quantize_roundtrip_bounds(rng):
    x = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x)).max()
    assert err <= float(s) / 2 + 1e-7


def test_error_feedback_unbiased_over_time(rng):
    g = {"w": jnp.asarray(rng.normal(size=(128,)) * 1e-3, jnp.float32)}
    err = init_error_state(g)
    acc = jnp.zeros(128)
    acc_q = jnp.zeros(128)
    for _ in range(50):
        (q, s), err = compress_tree(g, err)
        acc = acc + g["w"]
        acc_q = acc_q + decompress_tree(q, s, g)["w"]
    rel = float(jnp.linalg.norm(acc - acc_q) / jnp.linalg.norm(acc))
    assert rel < 0.01


# -- runtime ---------------------------------------------------------------------------

def test_straggler_monitor():
    mon = StragglerMonitor(window=20, factor=2.0, min_samples=5)
    for _ in range(10):
        assert not mon.record(0.1)
    assert mon.record(0.5)
    assert mon.alarms == 1
    assert not mon.record(0.12)


def test_preemption_handler_simulation():
    h = PreemptionHandler()
    assert not h.preempted
    h.simulate()
    assert h.preempted


def test_run_with_restarts():
    calls = {"n": 0}

    def loop(state):
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("injected fault")
        return "done"

    restarts = []
    out = run_with_restarts(
        dict, loop, max_restarts=5, on_restart=lambda i, e: restarts.append(i)
    )
    assert out == "done" and calls["n"] == 3 and restarts == [1, 2]


def test_run_with_restarts_exhausts():
    def loop(state):
        raise RuntimeError("always fails")

    with pytest.raises(RuntimeError):
        run_with_restarts(dict, loop, max_restarts=2)
