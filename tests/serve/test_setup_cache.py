"""Setup-cache accounting, LRU order, and the cache-hit parity pins.

The acceptance claims for the pattern-keyed setup cache:

* hit/miss/eviction counters in the metrics registry agree with the lookup
  sequence, per tier;
* eviction follows LRU order (observable through ``SetupCache.keys``);
* a cache-hit solve is **bitwise identical** to the cold solve that populated
  the cache, and the dispatch log shows **zero** generation launches for it.
"""

import copy

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import XlaExecutor, use_executor
from repro.observability import metrics
from repro.serve import (
    ContinuousBatchEngine,
    PatternSetup,
    ServeConfig,
    SetupCache,
    SolveRequest,
    TrafficConfig,
    generate_traffic,
)
from repro.solvers import Stop

STOP = Stop(max_iters=200, reduction_factor=1e-5)


def _stub_entry(tag: int) -> PatternSetup:
    """Cheap pattern entry for pure LRU bookkeeping tests."""
    n = 4
    indptr = np.arange(n + 1, dtype=np.int64)
    indices = np.full(n, tag % n, np.int64)
    return PatternSetup(key="", indptr=indptr, indices=indices,
                        shape=(n, n), fmt="csr")


def test_pattern_tier_hit_miss_accounting():
    metrics.reset()
    cache = SetupCache(capacity=8)
    for k in ("a", "b", "a", "c", "a", "b"):
        cache.setup(k, build=lambda: _stub_entry(0))
    stats = cache.stats()
    assert stats["serve_cache_misses_pattern"] == 3  # a, b, c
    assert stats["serve_cache_hits_pattern"] == 3  # a, a, b
    assert stats["serve_cache_evictions_pattern"] == 0
    # the counters are ordinary registry series, visible to samples()
    assert metrics.counter("serve_cache_hits", tier="pattern").value == 3


def test_pattern_tier_lru_eviction_order():
    metrics.reset()
    cache = SetupCache(capacity=2)
    cache.setup("a", build=lambda: _stub_entry(0))
    cache.setup("b", build=lambda: _stub_entry(1))
    assert cache.keys == ("a", "b")
    # touching `a` makes `b` the LRU victim
    _, hit = cache.setup("a", build=lambda: _stub_entry(0))
    assert hit
    cache.setup("c", build=lambda: _stub_entry(2))  # evicts b
    assert cache.keys == ("a", "c")
    assert "b" not in cache
    assert cache.stats()["serve_cache_evictions_pattern"] == 1
    # re-adding b is a miss again and evicts a (now LRU)
    _, hit = cache.setup("b", build=lambda: _stub_entry(1))
    assert not hit
    assert cache.keys == ("c", "b")


def test_values_tier_lru_and_accounting():
    metrics.reset()
    cache = SetupCache(capacity=4, factors_capacity=2)
    entry, _ = cache.setup("p", build=lambda: _stub_entry(0))
    mk = lambda v: jnp.full((1, 2, 2), float(v))
    cache.factors(entry, "f1", build=lambda: mk(1))
    cache.factors(entry, "f2", build=lambda: mk(2))
    inv, hit = cache.factors(entry, "f1", build=lambda: mk(-1))
    assert hit and float(inv[0, 0, 0]) == 1.0  # cached, not rebuilt
    cache.factors(entry, "f3", build=lambda: mk(3))  # evicts f2 (LRU)
    assert tuple(entry.factors) == ("f1", "f3")
    stats = cache.stats()
    assert stats["serve_cache_misses_values"] == 3
    assert stats["serve_cache_hits_values"] == 1
    assert stats["serve_cache_evictions_values"] == 1


def test_capacity_validation():
    with pytest.raises(ValueError):
        SetupCache(capacity=0)
    with pytest.raises(ValueError):
        SetupCache(capacity=4, factors_capacity=0)


def _one_request(seed: int) -> SolveRequest:
    cfg = TrafficConfig(num_requests=1, gallery_size=1, repeat_ratio=0.0,
                        n=16, seed=seed)
    return generate_traffic(cfg)[0][1]


def test_cache_hit_solve_bitwise_identical_to_cold():
    """The central pin: a warmed cache changes *nothing* about the numerics —
    the hit request skips generation entirely (zero ``serve_generate_*``
    dispatches) and produces a bitwise-identical solution."""
    metrics.reset()
    ex = XlaExecutor()
    config = ServeConfig(slots=4, chunk_sweeps=3, stop=STOP)
    req = _one_request(0)

    cold = ContinuousBatchEngine(config, executor=ex)
    cold.submit(copy.deepcopy(req))
    (r_cold,) = cold.drain()
    assert r_cold.converged
    assert not r_cold.pattern_hit and not r_cold.factors_hit

    # fresh engine, shared (warm) cache: both tiers hit, no generation runs
    warm = ContinuousBatchEngine(config, executor=ex, cache=cold.cache)
    ex.dispatch_log.clear()
    warm.submit(copy.deepcopy(req))
    (r_warm,) = warm.drain()
    log = dict(ex.dispatch_log)
    assert r_warm.pattern_hit and r_warm.factors_hit
    assert log.get("serve_generate_pattern", 0) == 0
    assert log.get("serve_generate_factors", 0) == 0
    assert np.array_equal(r_cold.x, r_warm.x)
    assert r_cold.iterations == r_warm.iterations
    assert r_cold.residual_norm == r_warm.residual_norm


def test_cold_request_logs_generation_dispatches():
    """Cold path control for the pin above: misses *do* launch generation."""
    metrics.reset()
    ex = XlaExecutor()
    engine = ContinuousBatchEngine(
        ServeConfig(slots=2, chunk_sweeps=4, stop=STOP), executor=ex
    )
    ex.dispatch_log.clear()
    engine.submit(_one_request(3))
    engine.drain()
    log = dict(ex.dispatch_log)
    assert log.get("serve_generate_pattern", 0) == 1
    assert log.get("serve_generate_factors", 0) == 1


def test_engine_traffic_hit_accounting():
    """Under repeat-heavy traffic the cache hit counters must line up with
    the per-response hit flags."""
    metrics.reset()
    ex = XlaExecutor()
    config = ServeConfig(slots=4, chunk_sweeps=4, stop=STOP)
    engine = ContinuousBatchEngine(config, executor=ex)
    traffic = generate_traffic(TrafficConfig(
        num_requests=16, gallery_size=2, repeat_ratio=0.6, n=16, seed=1,
    ))
    for _, req in traffic:
        engine.submit(req)
    responses = engine.drain()
    assert len(responses) == 16
    p_hits = sum(r.pattern_hit for r in responses)
    f_hits = sum(r.factors_hit for r in responses)
    stats = engine.cache.stats()
    assert stats["serve_cache_hits_pattern"] == p_hits
    assert stats["serve_cache_misses_pattern"] == 16 - p_hits
    assert stats["serve_cache_hits_values"] == f_hits
    assert p_hits > 0 and f_hits > 0  # repeat traffic actually hits
