"""Async service front end: the threaded boundary changes no outcomes."""

import copy

import numpy as np
import pytest

from repro.core import XlaExecutor
from repro.serve import (
    ContinuousBatchEngine,
    ServeConfig,
    SolveService,
    TrafficConfig,
    generate_traffic,
)
from repro.solvers import Stop

STOP = Stop(max_iters=200, reduction_factor=1e-5)
CONFIG = ServeConfig(slots=4, chunk_sweeps=4, stop=STOP)


def _traffic(num, seed=0):
    return generate_traffic(TrafficConfig(
        num_requests=num, gallery_size=2, repeat_ratio=0.5, n=16, seed=seed,
    ))


def test_submit_gather_round_trip():
    traffic = _traffic(10, seed=11)
    with SolveService(CONFIG, executor=XlaExecutor()) as svc:
        ids = [svc.submit(req) for _, req in traffic]
        responses = svc.gather(ids, timeout=120.0)
    assert [r.request_id for r in responses] == ids
    assert all(r.converged for r in responses)
    assert all(r.latency_s is not None and r.latency_s > 0
               for r in responses)


def test_service_matches_inline_engine():
    """The async queue is plumbing only: responses are bitwise the inline
    engine's for the same submission order and configuration."""
    traffic = _traffic(8, seed=12)
    ex = XlaExecutor()
    engine = ContinuousBatchEngine(CONFIG, executor=ex)
    inline = {}
    for _, req in traffic:
        rid = engine.submit(copy.deepcopy(req))
        inline[rid] = None
    for resp in engine.drain():
        inline[resp.request_id] = resp

    with SolveService(CONFIG, executor=ex) as svc:
        ids = [svc.submit(req) for _, req in traffic]
        served = svc.gather(ids, timeout=120.0)
    # service assigns its own ids starting at 0, same order as the engine's
    for resp in served:
        ref = inline[resp.request_id]
        assert np.array_equal(resp.x, ref.x)
        assert resp.iterations == ref.iterations


def test_result_timeout():
    with SolveService(CONFIG, executor=XlaExecutor()) as svc:
        with pytest.raises(TimeoutError):
            svc.result(10_000, timeout=0.05)


def test_submit_before_start_raises():
    svc = SolveService(CONFIG, executor=XlaExecutor())
    (_, req), = _traffic(1, seed=13)
    with pytest.raises(RuntimeError):
        svc.submit(req)
