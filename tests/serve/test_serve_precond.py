"""Serve preconditioner lanes beyond block-Jacobi: ParILU and AMG.

The PR-8 engine cached only block-Jacobi factors in the values tier; this
module pins the generalized seam: ``precond="parilu"`` caches the Chow–Patel
sweep factors ``[L | U]`` and ``precond="amg"`` caches the two-level row
``[inv_diag | A_c⁻¹]`` — both as flat per-system rows in the same
pattern-keyed :class:`~repro.serve.cache.SetupCache`, with the same zero-
generate-dispatch guarantee on cache hits.
"""

import numpy as np
import pytest

from repro.core import XlaExecutor
from repro.serve import ContinuousBatchEngine, ServeConfig
from repro.serve.request import SolveRequest
from repro.solvers import Stop
from repro.sparse.gallery import poisson_2d

STOP = Stop(max_iters=300, reduction_factor=1e-6)


def _requests(count, seed=0, n_side=8, scale=None):
    indptr, indices, values, shape = poisson_2d(n_side)
    rng = np.random.default_rng(seed)
    out = []
    for i in range(count):
        vals = values.astype(np.float32)
        if scale is not None:
            vals = vals * np.float32(scale[i % len(scale)])
        out.append(SolveRequest(
            indptr=indptr, indices=indices, values=vals,
            b=rng.normal(size=shape[0]).astype(np.float32), shape=shape,
        ))
    return out


def _dense(req) -> np.ndarray:
    n = req.shape[0]
    a = np.zeros((n, n), np.float32)
    for i in range(n):
        lo, hi = int(req.indptr[i]), int(req.indptr[i + 1])
        a[i, req.indices[lo:hi]] = req.values[lo:hi]
    return a


@pytest.mark.parametrize("precond,solver", [
    ("parilu", "bicgstab"),
    ("parilu", "cg"),
    ("amg", "cg"),
])
def test_lane_converges_to_true_solution(precond, solver):
    ex = XlaExecutor()
    engine = ContinuousBatchEngine(
        ServeConfig(slots=4, chunk_sweeps=4, solver=solver, precond=precond,
                    stop=STOP),
        executor=ex,
    )
    reqs = _requests(5, seed=1)
    ids = [engine.submit(r) for r in reqs]
    responses = engine.drain()
    assert sorted(r.request_id for r in responses) == sorted(ids)
    by_id = {r.request_id: r for r in responses}
    for req, rid in zip(reqs, ids):
        resp = by_id[rid]
        assert resp.converged
        res = np.linalg.norm(req.b - _dense(req) @ resp.x)
        assert res <= 1e-3 * np.linalg.norm(req.b)


@pytest.mark.parametrize("precond,solver", [
    ("parilu", "bicgstab"),
    ("amg", "cg"),
])
def test_cached_hit_issues_zero_generate_dispatches(precond, solver):
    """Repeat (pattern, values) traffic must touch neither generate op —
    the dispatch log is the proof, same contract as the block-Jacobi tier."""
    ex = XlaExecutor()
    engine = ContinuousBatchEngine(
        ServeConfig(slots=2, chunk_sweeps=4, solver=solver, precond=precond,
                    stop=STOP),
        executor=ex,
    )
    cold, warm = _requests(2, seed=2)
    engine.submit(cold)
    (cold_resp,) = engine.drain()
    assert not cold_resp.pattern_hit and not cold_resp.factors_hit

    ex.dispatch_log.clear()
    engine.submit(warm)
    (warm_resp,) = engine.drain()
    assert warm_resp.pattern_hit and warm_resp.factors_hit
    assert ex.dispatch_log.get("serve_generate_pattern", 0) == 0
    assert ex.dispatch_log.get("serve_generate_factors", 0) == 0


def test_same_pattern_new_values_regenerates_factors_only():
    ex = XlaExecutor()
    engine = ContinuousBatchEngine(
        ServeConfig(slots=2, chunk_sweeps=4, solver="cg", precond="amg",
                    stop=STOP),
        executor=ex,
    )
    r1, r2 = _requests(2, seed=3, scale=(1.0, 2.5))
    engine.submit(r1)
    engine.drain()
    ex.dispatch_log.clear()
    engine.submit(r2)
    (resp,) = engine.drain()
    assert resp.converged
    assert resp.pattern_hit and not resp.factors_hit
    assert ex.dispatch_log.get("serve_generate_pattern", 0) == 0
    assert ex.dispatch_log.get("serve_generate_factors", 0) == 1


def test_parilu_and_amg_share_cache_namespace():
    """Distinct precond configs must key distinct pattern entries — the same
    sparsity pattern under two engines never collides in a shared cache."""
    from repro.serve import SetupCache

    ex = XlaExecutor()
    cache = SetupCache()
    reqs = _requests(2, seed=4)
    e1 = ContinuousBatchEngine(
        ServeConfig(slots=2, solver="cg", precond="amg", stop=STOP),
        executor=ex, cache=cache,
    )
    e2 = ContinuousBatchEngine(
        ServeConfig(slots=2, solver="bicgstab", precond="parilu", stop=STOP),
        executor=ex, cache=cache,
    )
    e1.submit(reqs[0])
    (ra,) = e1.drain()
    e2.submit(reqs[1])
    (rb,) = e2.drain()
    assert ra.converged and rb.converged
    assert not rb.pattern_hit  # different config part of the key
    assert len(cache) == 2


def test_unknown_precond_rejected():
    ex = XlaExecutor()
    engine = ContinuousBatchEngine(
        ServeConfig(slots=2, precond="ilu0", stop=STOP), executor=ex
    )
    with pytest.raises(ValueError, match="unknown serve preconditioner"):
        engine.submit(_requests(1)[0])
