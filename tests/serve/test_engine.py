"""Continuous-batching engine: parity, convergence, retire semantics.

The parity invariant pinned here is deliberate: a request served in a busy
engine (other systems in flight, arbitrary slot position and admission tick)
must produce a **bitwise identical** solution to the same request served
alone in a fresh engine with the same configuration.  Every batched op in
the masked Krylov loop reduces row-independently and frozen rows ride
through unchanged, so slot traffic cannot perturb a lane row.

(Bitwise parity against a standalone ``nb=1`` ``batch_cg`` is *not* claimed:
XLA may order reductions differently across batch sizes.  Iteration counts
match it exactly; values match to roundoff.)
"""

import copy

import numpy as np
import jax.numpy as jnp
import pytest

from repro import batch, precond
from repro.core import XlaExecutor, use_executor
from repro.observability import metrics
from repro.serve import (
    ContinuousBatchEngine,
    ServeConfig,
    SetupCache,
    TrafficConfig,
    generate_traffic,
)
from repro.solvers import Stop

STOP = Stop(max_iters=200, reduction_factor=1e-5)


def _dense(req) -> np.ndarray:
    n = req.shape[0]
    a = np.zeros((n, n), np.float32)
    for i in range(n):
        lo, hi = int(req.indptr[i]), int(req.indptr[i + 1])
        a[i, req.indices[lo:hi]] = req.values[lo:hi]
    return a


def _traffic(num, seed=0, gallery=2, repeat=0.5, n=16):
    return generate_traffic(TrafficConfig(
        num_requests=num, gallery_size=gallery, repeat_ratio=repeat,
        n=n, seed=seed,
    ))


def test_mixed_stream_drains_and_converges():
    metrics.reset()
    ex = XlaExecutor()
    engine = ContinuousBatchEngine(
        ServeConfig(slots=4, chunk_sweeps=4, stop=STOP), executor=ex
    )
    traffic = _traffic(20, seed=2, gallery=3, repeat=0.6)
    ids = [engine.submit(req) for _, req in traffic]
    responses = engine.drain()
    assert sorted(r.request_id for r in responses) == sorted(ids)
    by_id = {r.request_id: r for r in responses}
    for (_, req), rid in zip(traffic, ids):
        resp = by_id[rid]
        assert resp.converged
        # true residual of the returned iterate, not the solver's recurrence
        res = np.linalg.norm(req.b - _dense(req) @ resp.x)
        assert res <= 1e-3 * np.linalg.norm(req.b)
    assert metrics.counter("serve_solves").value == 20
    assert metrics.counter("serve_failures").value == 0


def test_more_requests_than_slots():
    """Continuous batching: pending requests flow into slots as others
    retire; every request completes."""
    ex = XlaExecutor()
    engine = ContinuousBatchEngine(
        ServeConfig(slots=2, chunk_sweeps=3, stop=STOP), executor=ex
    )
    traffic = _traffic(9, seed=4, gallery=2, repeat=0.4)
    ids = [engine.submit(req) for _, req in traffic]
    responses = engine.drain()
    assert sorted(r.request_id for r in responses) == sorted(ids)
    assert all(r.converged for r in responses)


def test_busy_vs_solo_serve_bitwise():
    """A request in a busy engine == the same request served alone."""
    ex = XlaExecutor()
    config = ServeConfig(slots=4, chunk_sweeps=3, stop=STOP)
    traffic = _traffic(8, seed=7, gallery=2, repeat=0.5)

    busy = ContinuousBatchEngine(config, executor=ex)
    solo_reqs = [copy.deepcopy(req) for _, req in traffic]
    ids = [busy.submit(req) for _, req in traffic]
    busy_by_id = {r.request_id: r for r in busy.drain()}

    # one shared cache across the solo engines: cached factors/closures are
    # deterministic, so sharing only saves compile time, never changes bits
    solo_cache = SetupCache()
    for req, rid in zip(solo_reqs, ids):
        solo = ContinuousBatchEngine(config, executor=ex, cache=solo_cache)
        solo.submit(req)
        (solo_resp,) = solo.drain()
        busy_resp = busy_by_id[rid]
        assert np.array_equal(busy_resp.x, solo_resp.x), (
            f"request {rid}: busy-lane solve diverged from solo serve"
        )
        assert busy_resp.iterations == solo_resp.iterations
        assert busy_resp.residual_norm == solo_resp.residual_norm


def test_solo_serve_matches_batch_cg():
    """Iteration counts equal the standalone preconditioned batch_cg;
    iterates agree to roundoff (reduction order may differ across batch
    sizes, so bitwise is not claimed here — see module docstring)."""
    ex = XlaExecutor()
    config = ServeConfig(slots=4, chunk_sweeps=3, stop=STOP, block_size=4)
    (_, req), = _traffic(1, seed=5, gallery=1, repeat=0.0)
    engine = ContinuousBatchEngine(config, executor=ex)
    engine.submit(copy.deepcopy(req))
    (resp,) = engine.drain()

    with use_executor(ex):
        A = batch.BatchCsr(
            jnp.asarray(req.indptr, jnp.int32),
            jnp.asarray(req.indices, jnp.int32),
            jnp.asarray(req.values)[None, :],
            req.shape,
        )
        M = precond.batch_block_jacobi(A, 4)
        ref = batch.batch_cg(A, jnp.asarray(req.b)[None, :], stop=STOP, M=M)
    assert resp.converged and bool(ref.converged[0])
    assert resp.iterations == int(ref.iterations[0])
    np.testing.assert_allclose(resp.x, np.asarray(ref.x[0]),
                               rtol=1e-5, atol=1e-6)


def test_iteration_cap_retires_unconverged():
    """Per-request max_iters is enforced host-side at retire: a hopeless
    stop target still terminates, reports converged=False, and counts as a
    serve failure."""
    metrics.reset()
    ex = XlaExecutor()
    hard = Stop(max_iters=3, reduction_factor=1e-30)
    engine = ContinuousBatchEngine(
        ServeConfig(slots=2, chunk_sweeps=1, stop=hard), executor=ex
    )
    (_, req), = _traffic(1, seed=6, gallery=1, repeat=0.0)
    engine.submit(req)
    (resp,) = engine.drain()
    assert not resp.converged
    # chunk_sweeps=1 makes the host check exact, not chunk-granular
    assert resp.iterations == 3
    assert metrics.counter("serve_failures").value == 1


def test_latency_histogram_feeds_quantiles():
    """Retire must observe per-request latency into the sub-unit-bucketed
    histogram the driver reads p50/p99 from."""
    ex = XlaExecutor()
    engine = ContinuousBatchEngine(
        ServeConfig(slots=4, chunk_sweeps=4, stop=STOP), executor=ex
    )
    # warm pass absorbs jit compilation, then measure steady-state latencies
    for _, req in _traffic(6, seed=8):
        engine.submit(req)
    engine.drain()
    metrics.reset()
    for _, req in _traffic(6, seed=88):
        engine.submit(req)
    responses = engine.drain()
    assert all(r.latency_s is not None and r.latency_s > 0
               for r in responses)
    h = metrics.histogram("serve_latency_s")
    p50, p99 = h.quantile(0.5), h.quantile(0.99)
    assert p50 is not None and p99 is not None
    assert 0 < p50 <= p99
    # serving latencies are sub-second: the satellite-1 bucket fix is what
    # makes these quantiles resolvable at all
    assert p50 < 1.0


def test_ell_lane_agrees_with_csr():
    ex = XlaExecutor()
    (_, req), = _traffic(1, seed=9, gallery=1, repeat=0.0)
    results = {}
    for fmt in ("csr", "ell"):
        engine = ContinuousBatchEngine(
            ServeConfig(slots=2, chunk_sweeps=4, stop=STOP, fmt=fmt),
            executor=ex,
        )
        engine.submit(copy.deepcopy(req))
        (results[fmt],) = engine.drain()
    assert results["csr"].converged and results["ell"].converged
    np.testing.assert_allclose(results["ell"].x, results["csr"].x,
                               rtol=1e-5, atol=1e-6)


def test_bicgstab_engine_converges():
    ex = XlaExecutor()
    engine = ContinuousBatchEngine(
        ServeConfig(slots=3, chunk_sweeps=4, solver="bicgstab", stop=STOP),
        executor=ex,
    )
    traffic = _traffic(5, seed=10, gallery=2, repeat=0.5)
    for _, req in traffic:
        engine.submit(req)
    responses = engine.drain()
    assert len(responses) == 5
    for (_, req), resp in zip(traffic, sorted(responses,
                                              key=lambda r: r.request_id)):
        assert resp.converged
        res = np.linalg.norm(req.b - _dense(req) @ resp.x)
        assert res <= 1e-3 * np.linalg.norm(req.b)


def test_degenerate_stop_rejected_at_construction():
    with pytest.raises(ValueError):
        ContinuousBatchEngine(ServeConfig(
            stop=Stop(max_iters=10, reduction_factor=0.0, abs_tol=0.0)
        ), executor=XlaExecutor())


def test_nonsym_traffic_served_by_bicgstab_engine():
    """Nonsymmetric gallery traffic (convection-diffusion patterns mixed in
    via ``nonsym_ratio``) must flow through a bicgstab engine end to end,
    every request converging with a small *true* residual."""
    ex = XlaExecutor()
    engine = ContinuousBatchEngine(
        ServeConfig(slots=3, chunk_sweeps=4, solver="bicgstab",
                    stop=Stop(max_iters=300, reduction_factor=1e-5)),
        executor=ex,
    )
    traffic = generate_traffic(TrafficConfig(
        num_requests=12, gallery_size=2, repeat_ratio=0.0,
        n=25, seed=3, nonsym_ratio=0.7,
    ))
    dense = {id(req): _dense(req) for _, req in traffic}
    nonsym = sum(
        1 for _, req in traffic
        if not np.allclose(dense[id(req)], dense[id(req)].T, atol=1e-6)
    )
    assert nonsym >= 3, f"only {nonsym}/12 requests drew a nonsym pattern"
    by_id = {}
    for _, req in traffic:
        by_id[engine.submit(req)] = req
    responses = engine.drain()
    assert len(responses) == len(traffic)
    for resp in responses:
        req = by_id[resp.request_id]
        assert resp.converged
        res = np.linalg.norm(req.b - dense[id(req)] @ resp.x)
        assert res <= 1e-3 * np.linalg.norm(req.b)


def test_nonsym_ratio_requires_square_grid_size():
    with pytest.raises(ValueError, match="square"):
        generate_traffic(TrafficConfig(
            num_requests=2, gallery_size=1, n=17, nonsym_ratio=0.5,
        ))
