"""ParILU (Chow-Patel) fixed-point factorization + iterative triangular solves."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro import solvers, sparse
from repro.core import XlaExecutor, use_executor
from repro.solvers.parilu import parilu_factorize, parilu_preconditioner


def test_full_pattern_converges_to_exact_lu(rng):
    """With a dense sparsity pattern, the sweeps converge to the exact LU."""
    n = 12
    a = rng.normal(size=(n, n)).astype(np.float32)
    a = a @ a.T + n * np.eye(n, dtype=np.float32)
    A = sparse.csr_from_dense(a)
    l_vals, u_vals, st = parilu_factorize(A, sweeps=40)
    L = np.eye(n, dtype=np.float32)
    U = np.zeros((n, n), np.float32)
    L[st.l_rows, st.l_cols] = np.asarray(l_vals)
    U[st.u_rows, st.u_cols] = np.asarray(u_vals)
    assert np.abs(L @ U - a).max() / np.abs(a).max() < 1e-4


def test_sparse_pattern_residual_decreases(rng):
    """More sweeps monotonically shrink ||A - (LU)|_S||."""
    n = 64
    a = np.zeros((n, n), np.float32)
    for i in range(n):
        a[i, i] = 4.0
        if i > 0:
            a[i, i - 1] = a[i - 1, i] = -1.0
        if i > 4:
            a[i, i - 5] = a[i - 5, i] = -0.7
    A = sparse.csr_from_dense(a)

    def pattern_residual(sweeps):
        l_vals, u_vals, st = parilu_factorize(A, sweeps=sweeps)
        L = np.eye(n, dtype=np.float32)
        U = np.zeros((n, n), np.float32)
        L[st.l_rows, st.l_cols] = np.asarray(l_vals)
        U[st.u_rows, st.u_cols] = np.asarray(u_vals)
        prod = L @ U
        mask = np.asarray(a != 0)
        return np.abs((prod - a) * mask).max()

    r1, r3, r6 = pattern_residual(1), pattern_residual(3), pattern_residual(6)
    assert r6 <= r3 + 1e-6
    assert r6 < r1


def test_parilu_preconditioned_cg_beats_plain(rng):
    n = 120
    a = np.zeros((n, n), np.float32)
    for i in range(n):
        a[i, i] = 4.0
        if i > 0:
            a[i, i - 1] = a[i - 1, i] = -1.0
        if i > 4:
            a[i, i - 5] = a[i - 5, i] = -0.8
    xstar = rng.normal(size=n).astype(np.float32)
    b = (a @ xstar).astype(np.float32)
    A = sparse.csr_from_dense(a)
    stop = solvers.Stop(max_iters=500, reduction_factor=1e-6)
    with use_executor(XlaExecutor()):
        plain = solvers.cg(A, jnp.asarray(b), stop=stop)
        M = parilu_preconditioner(A, factor_sweeps=5, solve_sweeps=8)
        ilu = solvers.cg(A, jnp.asarray(b), stop=stop, M=M)
    assert bool(ilu.converged)
    np.testing.assert_allclose(ilu.x, xstar, atol=1e-3)
    assert int(ilu.iterations) < int(plain.iterations) // 2


def test_parilu_on_nonsymmetric_bicgstab(rng):
    n = 80
    a = np.zeros((n, n), np.float32)
    for i in range(n):
        a[i, i] = 5.0
        if i > 0:
            a[i, i - 1] = -1.4
        if i < n - 1:
            a[i, i + 1] = -0.6
    xstar = rng.normal(size=n).astype(np.float32)
    b = (a @ xstar).astype(np.float32)
    A = sparse.csr_from_dense(a)
    stop = solvers.Stop(max_iters=400, reduction_factor=1e-6)
    with use_executor(XlaExecutor()):
        M = parilu_preconditioner(A)
        res = solvers.bicgstab(A, jnp.asarray(b), stop=stop, M=M)
    assert bool(res.converged)
    np.testing.assert_allclose(res.x, xstar, atol=1e-3)
