"""Krylov solvers: convergence on SPD/nonsymmetric systems, all formats."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import solvers, sparse
from repro.core import ReferenceExecutor, XlaExecutor, use_executor


def spd_system(n=96, rng=None):
    rng = rng or np.random.default_rng(3)
    a = np.zeros((n, n), np.float32)
    for i in range(n):
        a[i, i] = 4.0
        if i > 0:
            a[i, i - 1] = a[i - 1, i] = -1.0
        if i > 2:
            a[i, i - 3] = a[i - 3, i] = -0.5
    x = rng.normal(size=n).astype(np.float32)
    return a, x, (a @ x).astype(np.float32)


def nonsym_system(n=96, rng=None):
    rng = rng or np.random.default_rng(4)
    a, x, _ = spd_system(n, rng)
    a = a + np.triu(rng.normal(size=(n, n)).astype(np.float32) * 0.05, 1)
    return a, x, (a @ x).astype(np.float32)


STOP = solvers.Stop(max_iters=500, reduction_factor=1e-6)


@pytest.mark.parametrize("fn", [solvers.cg, solvers.fcg])
@pytest.mark.parametrize("fmt", ["csr", "ell", "sellp", "coo"])
def test_spd_solvers_all_formats(fn, fmt):
    a, xstar, b = spd_system()
    A = getattr(sparse, f"{fmt}_from_dense")(a)
    with use_executor(XlaExecutor()):
        res = jax.jit(lambda b: fn(A, b, stop=STOP))(jnp.asarray(b))
    assert bool(res.converged)
    np.testing.assert_allclose(res.x, xstar, atol=1e-3)


@pytest.mark.parametrize("fn", [solvers.bicgstab, solvers.gmres])
def test_nonsymmetric_solvers(fn):
    a, xstar, b = nonsym_system()
    A = sparse.csr_from_dense(a)
    with use_executor(XlaExecutor()):
        res = jax.jit(lambda b: fn(A, b, stop=STOP))(jnp.asarray(b))
    assert bool(res.converged)
    np.testing.assert_allclose(res.x, xstar, atol=5e-2)


def test_jacobi_preconditioner_reduces_iterations():
    rng = np.random.default_rng(5)
    n = 120
    # badly scaled diagonal: Jacobi should help a lot
    d = 10.0 ** rng.uniform(-2, 2, size=n)
    a, _, _ = spd_system(n, rng)
    a = a * np.sqrt(d[:, None] * d[None, :])
    xstar = rng.normal(size=n).astype(np.float32)
    b = (a @ xstar).astype(np.float32)
    A = sparse.csr_from_dense(a.astype(np.float32))
    with use_executor(XlaExecutor()):
        plain = solvers.cg(A, jnp.asarray(b), stop=solvers.Stop(max_iters=2000, reduction_factor=1e-6))
        M = solvers.jacobi_preconditioner(A)
        pre = solvers.cg(A, jnp.asarray(b), stop=solvers.Stop(max_iters=2000, reduction_factor=1e-6), M=M)
    assert bool(pre.converged)
    assert int(pre.iterations) < int(plain.iterations)


def test_reference_executor_oracle():
    a, xstar, b = spd_system(48)
    A = sparse.csr_from_dense(a)
    with use_executor(ReferenceExecutor()):
        res = solvers.cg(A, jnp.asarray(b), stop=STOP)
    assert bool(res.converged)
    np.testing.assert_allclose(res.x, xstar, atol=1e-3)


def test_matvec_callable_operator():
    a, xstar, b = spd_system(48)
    A = jnp.asarray(a)
    with use_executor(XlaExecutor()):
        res = solvers.cg(lambda v: A @ v, jnp.asarray(b), stop=STOP)
    assert bool(res.converged)


def test_stop_criterion_max_iters():
    a, _, b = spd_system(48)
    A = sparse.csr_from_dense(a)
    with use_executor(XlaExecutor()):
        res = solvers.cg(A, jnp.asarray(b), stop=solvers.Stop(max_iters=2, reduction_factor=1e-12))
    assert int(res.iterations) == 2
    assert not bool(res.converged)


def test_gmres_restart_sweep():
    a, xstar, b = nonsym_system(64)
    A = sparse.csr_from_dense(a)
    with use_executor(XlaExecutor()):
        for m in (5, 10, 20):
            res = solvers.gmres(A, jnp.asarray(b), restart=m, stop=STOP)
            assert bool(res.converged), m


def test_gmres_multiple_restart_cycles():
    """A system that cannot converge within one Krylov cycle of size m needs
    >1 restart; the solver must still converge and report the *cumulative*
    iteration count (a multiple of m, more than one cycle's worth)."""
    a, xstar, b = nonsym_system(96)
    A = sparse.csr_from_dense(a)
    m = 4  # far below the ~n Krylov dimension this system wants
    with use_executor(XlaExecutor()):
        res = solvers.gmres(
            A, jnp.asarray(b), restart=m,
            stop=solvers.Stop(max_iters=400, reduction_factor=1e-6),
        )
    assert bool(res.converged)
    k = int(res.iterations)
    assert k > m, f"expected >1 restart cycle, got {k} iterations"
    assert k % m == 0, f"cumulative count {k} must be whole cycles of {m}"
    np.testing.assert_allclose(res.x, xstar, atol=5e-2)


def test_stop_degenerate_criterion_raises():
    """abs_tol-only stopping works; the all-zero criterion raises instead of
    silently returning threshold 0.0 (which can never be met)."""
    a, xstar, b = spd_system(48)
    A = sparse.csr_from_dense(a)
    with use_executor(XlaExecutor()):
        res = solvers.cg(
            A, jnp.asarray(b),
            stop=solvers.Stop(max_iters=500, reduction_factor=0.0, abs_tol=1e-3),
        )
        assert bool(res.converged)
        assert float(res.residual_norm) <= 1e-3
        with pytest.raises(ValueError, match="degenerate stopping criterion"):
            solvers.cg(
                A, jnp.asarray(b),
                stop=solvers.Stop(reduction_factor=0.0, abs_tol=0.0),
            )


def test_block_jacobi_preconditioner():
    """Block-Jacobi (Ginkgo's flagship) beats scalar Jacobi on block systems."""
    rng = np.random.default_rng(8)
    n, bs = 96, 4
    a = np.zeros((n, n), np.float32)
    for s in range(0, n, bs):  # strong diag blocks + weak coupling
        blk = rng.normal(size=(bs, bs)).astype(np.float32)
        a[s : s + bs, s : s + bs] = blk @ blk.T + 4 * np.eye(bs)
    for i in range(n - bs):
        a[i, i + bs] = a[i + bs, i] = 0.1
    xstar = rng.normal(size=n).astype(np.float32)
    b = (a @ xstar).astype(np.float32)
    A = sparse.csr_from_dense(a)
    stop = solvers.Stop(max_iters=500, reduction_factor=1e-6)
    with use_executor(XlaExecutor()):
        plain = solvers.cg(A, jnp.asarray(b), stop=stop)
        mj = solvers.jacobi_preconditioner(A)
        scalar = solvers.cg(A, jnp.asarray(b), stop=stop, M=mj)
        mbj = solvers.block_jacobi_preconditioner(A, block_size=bs)
        block = solvers.cg(A, jnp.asarray(b), stop=stop, M=mbj)
    assert bool(block.converged)
    np.testing.assert_allclose(block.x, xstar, atol=1e-3)
    assert int(block.iterations) <= int(scalar.iterations)
    assert int(block.iterations) < int(plain.iterations)


def test_block_jacobi_bs1_matches_scalar():
    rng = np.random.default_rng(9)
    a, xstar, b = spd_system(48, rng)
    A = sparse.csr_from_dense(a)
    with use_executor(XlaExecutor()):
        m1 = solvers.jacobi_preconditioner(A)
        m2 = solvers.block_jacobi_preconditioner(A, block_size=1)
        v = jnp.asarray(rng.normal(size=48).astype(np.float32))
        np.testing.assert_allclose(m1(v), m2(v), rtol=1e-5)


def test_block_jacobi_non_divisible_n():
    rng = np.random.default_rng(10)
    a, xstar, b = spd_system(50, rng)  # 50 % 4 != 0 -> padded trailing block
    A = sparse.csr_from_dense(a)
    with use_executor(XlaExecutor()):
        m = solvers.block_jacobi_preconditioner(A, block_size=4)
        res = solvers.cg(A, jnp.asarray(b), stop=STOP, M=m)
    assert bool(res.converged)
    np.testing.assert_allclose(res.x, xstar, atol=1e-3)


def test_cgs_nonsymmetric():
    a, xstar, b = nonsym_system()
    A = sparse.csr_from_dense(a)
    with use_executor(XlaExecutor()):
        res = jax.jit(lambda b: solvers.cgs(A, b, stop=STOP))(jnp.asarray(b))
    assert bool(res.converged)
    np.testing.assert_allclose(res.x, xstar, atol=5e-2)


def test_cgs_preconditioned():
    a, xstar, b = nonsym_system()
    A = sparse.csr_from_dense(a)
    with use_executor(XlaExecutor()):
        M = solvers.jacobi_preconditioner(A)
        res = solvers.cgs(A, jnp.asarray(b), stop=STOP, M=M)
    assert bool(res.converged)
