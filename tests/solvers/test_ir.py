"""Mixed-precision iterative refinement (solvers.ir) — the LinOp payoff.

The acceptance contract: an f32 inner CG under an f64 outer residual must
recover the f64 solution on the SPD regression matrices; plain Richardson and
preconditioner-inner variants must behave like the textbook iteration.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax import experimental as jax_experimental

from repro import solvers, sparse
from repro.core import (
    PallasInterpretExecutor,
    ReferenceExecutor,
    XlaExecutor,
    use_executor,
)
from repro.precond import unit_roundoff


def spd_dense(n=96, rng=None, dtype=np.float64):
    rng = rng or np.random.default_rng(3)
    a = np.zeros((n, n), dtype)
    for i in range(n):
        a[i, i] = 4.0
        if i > 0:
            a[i, i - 1] = a[i - 1, i] = -1.0
        if i > 2:
            a[i, i - 3] = a[i - 3, i] = -0.5
    return a


def blocked_spd_dense(n=128, bs=8, dtype=np.float64):
    """The adaptive block-Jacobi regression fixture, f64."""
    rng = np.random.default_rng(7)
    a = np.zeros((n, n), dtype)
    for s in range(0, n, bs):
        blk = rng.normal(size=(bs, bs))
        a[s : s + bs, s : s + bs] = blk @ blk.T + 4 * np.eye(bs)
    for i in range(n - bs):
        a[i, i + bs] = a[i + bs, i] = 0.05
    return a


F64_STOP = solvers.Stop(max_iters=100, reduction_factor=1e-12)


@pytest.mark.parametrize("fixture", [spd_dense, blocked_spd_dense])
def test_mixed_precision_ir_reaches_f64_tolerance(fixture):
    """f32 inner CG + x64 outer residual converges to the f64 tolerance —
    far below anything a pure-f32 solve can reach."""
    with jax_experimental.enable_x64(True):
        a = fixture()
        n = a.shape[0]
        A = sparse.csr_from_dense(a)
        assert A.dtype == jnp.float64
        rng = np.random.default_rng(0)
        xstar = rng.normal(size=n)
        b = jnp.asarray(a @ xstar)
        with use_executor(XlaExecutor()):
            res = solvers.mixed_precision_ir(A, b, stop=F64_STOP)
            pure32 = solvers.cg(
                A.astype(jnp.float32), b.astype(jnp.float32),
                stop=solvers.Stop(max_iters=2000, reduction_factor=1e-12),
            )
        assert bool(res.converged)
        assert res.x.dtype == jnp.float64
        # at the f64 tolerance, clearly below the f32 floor
        assert float(res.residual_norm) < 1e-9
        assert float(res.residual_norm) < 0.1 * float(pure32.residual_norm)
        np.testing.assert_allclose(np.asarray(res.x), xstar, atol=1e-8)


def test_mixed_precision_ir_outer_sweeps_are_few():
    """IR theory: each outer sweep contracts the error by ~ the inner solve
    accuracy; reaching 1e-12 from an sqrt(u_f32) ~ 2e-4 inner tolerance
    should take a handful of sweeps, not tens."""
    with jax_experimental.enable_x64(True):
        a = spd_dense()
        A = sparse.csr_from_dense(a)
        b = jnp.asarray(a @ np.ones(a.shape[0]))
        with use_executor(XlaExecutor()):
            res = solvers.mixed_precision_ir(A, b, stop=F64_STOP)
        assert bool(res.converged)
        assert int(res.iterations) <= 8, int(res.iterations)


@pytest.mark.parametrize(
    "exec_cls", [ReferenceExecutor, XlaExecutor, PallasInterpretExecutor]
)
def test_mixed_precision_ir_cross_executor(exec_cls):
    with jax_experimental.enable_x64(True):
        a = spd_dense(48)
        A = sparse.csr_from_dense(a)
        xstar = np.random.default_rng(1).normal(size=48)
        b = jnp.asarray(a @ xstar)
        with use_executor(exec_cls()):
            res = solvers.mixed_precision_ir(A, b, stop=F64_STOP)
        assert bool(res.converged), exec_cls.__name__
        np.testing.assert_allclose(np.asarray(res.x), xstar, atol=1e-8)


def test_plain_richardson():
    """inner=None degenerates to x += relaxation * r; converges for
    rho(I - omega*A) < 1 (here A ~ diag(4), omega = 0.2)."""
    a = spd_dense(64, dtype=np.float32)
    A = sparse.csr_from_dense(a)
    xstar = np.random.default_rng(2).normal(size=64).astype(np.float32)
    b = jnp.asarray(a @ xstar)
    with use_executor(XlaExecutor()):
        res = solvers.ir(
            A, b, relaxation=0.2,
            stop=solvers.Stop(max_iters=500, reduction_factor=1e-5),
        )
    assert bool(res.converged)
    np.testing.assert_allclose(res.x, xstar, atol=1e-3)


def test_ir_with_preconditioner_inner():
    """Any LinOp can be the inner operator — block-Jacobi IR is the classic
    'relaxation by approximate inverse'."""
    a = blocked_spd_dense(64, 8, dtype=np.float32)
    A = sparse.csr_from_dense(a)
    xstar = np.random.default_rng(4).normal(size=64).astype(np.float32)
    b = jnp.asarray(a @ xstar)
    with use_executor(XlaExecutor()):
        M = solvers.block_jacobi_preconditioner(A, block_size=8)
        res = solvers.ir(
            A, b, inner=M,
            stop=solvers.Stop(max_iters=500, reduction_factor=1e-5),
        )
    assert bool(res.converged)
    np.testing.assert_allclose(res.x, xstar, atol=1e-3)


def test_ir_respects_max_iters():
    a = spd_dense(32, dtype=np.float32)
    A = sparse.csr_from_dense(a)
    b = jnp.asarray((a @ np.ones(32)).astype(np.float32))
    with use_executor(XlaExecutor()):
        res = solvers.ir(
            A, b, relaxation=0.01,  # far too small to converge in 3 sweeps
            stop=solvers.Stop(max_iters=3, reduction_factor=1e-10),
        )
    assert int(res.iterations) == 3
    assert not bool(res.converged)


def test_ir_solver_factory_is_linop():
    """IrSolver composes like any operator — here preconditioning CG."""
    a = spd_dense(48, dtype=np.float32)
    A = sparse.csr_from_dense(a)
    xstar = np.random.default_rng(5).normal(size=48).astype(np.float32)
    b = jnp.asarray(a @ xstar)
    with use_executor(XlaExecutor()):
        S = solvers.IrSolver(
            A,
            inner=solvers.jacobi_preconditioner(A),
            stop=solvers.Stop(max_iters=20, reduction_factor=1e-2),
        )
        res = solvers.cg(
            A, b, M=S, stop=solvers.Stop(max_iters=200, reduction_factor=1e-5)
        )
    assert bool(res.converged)
    np.testing.assert_allclose(res.x, xstar, atol=1e-3)


def test_mixed_precision_ir_is_jittable():
    with jax_experimental.enable_x64(True):
        a = spd_dense(48)
        A = sparse.csr_from_dense(a)
        xstar = np.random.default_rng(6).normal(size=48)
        b = jnp.asarray(a @ xstar)
        with use_executor(XlaExecutor()):
            x = jax.jit(
                lambda b: solvers.mixed_precision_ir(A, b, stop=F64_STOP).x
            )(b)
        np.testing.assert_allclose(np.asarray(x), xstar, atol=1e-8)


def test_unit_roundoff_table():
    """The PR 3 precision machinery the IR budget reuses."""
    assert unit_roundoff(jnp.float16) == 2.0**-11
    assert unit_roundoff(jnp.bfloat16) == 2.0**-8
    assert unit_roundoff(jnp.float32) == 2.0**-24
    with jax_experimental.enable_x64(True):
        assert unit_roundoff(jnp.float64) == 2.0**-53


def test_mixed_precision_ir_requires_astype():
    with pytest.raises(TypeError, match="astype"):
        solvers.mixed_precision_ir(lambda v: v, jnp.ones(4, jnp.float32))


def test_ir_threads_executor_into_inner_operator():
    """The documented contract: executor= passed to ir() governs the whole
    operator subtree, inner solve included."""
    from repro.core import LinOp

    seen = []

    class Probe(LinOp):
        def _apply(self, v, executor):
            seen.append(executor)
            return v

    a = spd_dense(16, dtype=np.float32)
    A = sparse.csr_from_dense(a)
    b = jnp.asarray((a @ np.ones(16)).astype(np.float32))
    ex = XlaExecutor()
    solvers.ir(A, b, inner=Probe(),
               stop=solvers.Stop(max_iters=2, reduction_factor=1e-10),
               executor=ex)
    assert seen and all(e is ex for e in seen), seen
