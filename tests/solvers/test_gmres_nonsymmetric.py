"""GMRES restart behavior on the nonsymmetric gallery corpus.

Iteration counts are pinned per (Péclet regime, restart length) on the
convection-diffusion stencils — recorded on jax 0.4.37, f32, CPU, with 15%
slack for cross-platform float drift (counts are whole restart cycles, so the
slack usually rounds to the next cycle).  Also pinned qualitatively: in the
diffusion-dominated regime a too-short restart loses superlinear convergence
(classic Krylov-subspace truncation), while every regime still converges and
produces a solution whose *true* residual matches the recurrence's claim.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro import sparse, solvers
from repro.core import XlaExecutor, use_executor
from repro.sparse.gallery import convection_diffusion_2d

STOP = solvers.Stop(max_iters=1000, reduction_factor=1e-6)

REGIMES = {
    "diffusive_pe0p1": (0.1, "centered"),
    "balanced_pe1": (1.0, "upwind"),
    "advective_pe10": (10.0, "upwind"),
}

# (regime, restart) -> recorded iterations
RECORDED = {
    ("diffusive_pe0p1", 5): 125,
    ("diffusive_pe0p1", 10): 80,
    ("diffusive_pe0p1", 40): 80,
    ("balanced_pe1", 5): 55,
    ("balanced_pe1", 10): 70,
    ("balanced_pe1", 40): 80,
    ("advective_pe10", 5): 60,
    ("advective_pe10", 10): 90,
    ("advective_pe10", 40): 40,
}


def _system(regime):
    peclet, scheme = REGIMES[regime]
    indptr, indices, values, shape = convection_diffusion_2d(
        16, peclet=peclet, scheme=scheme
    )
    a = np.zeros(shape, np.float32)
    rows = np.repeat(np.arange(shape[0]), np.diff(indptr))
    a[rows, indices] = values
    A = sparse.csr_from_arrays(indptr, indices, values, shape)
    b = np.random.default_rng(0).normal(size=shape[0]).astype(np.float32)
    return a, A, b


def _bound(recorded: int) -> int:
    return int(np.ceil(recorded * 1.15))


@pytest.mark.parametrize("regime,restart", sorted(RECORDED))
def test_restart_iteration_pins(regime, restart):
    a, A, b = _system(regime)
    with use_executor(XlaExecutor()):
        res = solvers.gmres(A, jnp.asarray(b), stop=STOP, restart=restart)
    assert bool(res.converged), f"{regime} restart={restart} did not converge"
    k = int(res.iterations)
    assert k <= _bound(RECORDED[(regime, restart)]), (
        f"{regime} restart={restart}: {k} iterations exceeds recorded "
        f"bound {_bound(RECORDED[(regime, restart)])}"
    )
    rel = np.linalg.norm(b - a @ np.asarray(res.x)) / np.linalg.norm(b)
    assert rel <= 1e-4, f"true residual {rel:.2e} disagrees with convergence"


def test_short_restart_costs_iterations_in_diffusive_regime():
    """Krylov truncation: restart=5 must burn strictly more iterations than
    restart=40 on the diffusion-dominated system (near-symmetric spectrum,
    superlinear CG-like convergence that truncation destroys)."""
    _, A, b = _system("diffusive_pe0p1")
    with use_executor(XlaExecutor()):
        short = solvers.gmres(A, jnp.asarray(b), stop=STOP, restart=5)
        long = solvers.gmres(A, jnp.asarray(b), stop=STOP, restart=40)
    assert bool(short.converged) and bool(long.converged)
    assert int(short.iterations) > int(long.iterations), (
        f"restart=5 took {int(short.iterations)} <= restart=40's "
        f"{int(long.iterations)} — truncation penalty disappeared?"
    )


def test_gmres_solver_factory_forwards_restart():
    _, A, b = _system("advective_pe10")
    with use_executor(XlaExecutor()):
        via_fn = solvers.gmres(A, jnp.asarray(b), stop=STOP, restart=10)
        via_factory = solvers.GmresSolver(A, restart=10, stop=STOP).solve(
            jnp.asarray(b)
        )
    assert int(via_fn.iterations) == int(via_factory.iterations)
    np.testing.assert_allclose(
        np.asarray(via_fn.x), np.asarray(via_factory.x), atol=1e-6
    )
