"""The LinOp hierarchy: combinators, compat shims, solver-as-preconditioner.

Covers the unification contract: formats, preconditioners, and generated
solvers are all LinOps composing through one ``apply``; the historical
conventions (``LinearOperator`` wrappers, plain-callable ``M=``) keep working
through the deprecation shim.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import solvers, sparse
from repro.core import (
    Composition,
    Identity,
    LinOp,
    MatrixFreeOp,
    PallasInterpretExecutor,
    ReferenceExecutor,
    ScaledIdentity,
    Sum,
    Transpose,
    XlaExecutor,
    as_linop,
    use_executor,
)


def spd_system(n=96, rng=None):
    rng = rng or np.random.default_rng(3)
    a = np.zeros((n, n), np.float32)
    for i in range(n):
        a[i, i] = 4.0
        if i > 0:
            a[i, i - 1] = a[i - 1, i] = -1.0
        if i > 2:
            a[i, i - 3] = a[i - 3, i] = -0.5
    x = rng.normal(size=n).astype(np.float32)
    return a, x, (a @ x).astype(np.float32)


STOP = solvers.Stop(max_iters=500, reduction_factor=1e-6)


# =============================================================================
# The LinOp interface on every layer
# =============================================================================


def test_formats_are_linops():
    a, _, _ = spd_system(32)
    for build in (sparse.coo_from_dense, sparse.csr_from_dense,
                  sparse.ell_from_dense, sparse.sellp_from_dense):
        A = build(a)
        assert isinstance(A, LinOp)
        v = jnp.ones(32, jnp.float32)
        with use_executor(XlaExecutor()):
            np.testing.assert_allclose(A.apply(v), a @ np.ones(32), rtol=1e-4)
            # __call__ aliases the simple apply (the preconditioner face)
            np.testing.assert_allclose(A(v), A.apply(v), rtol=1e-6)
    assert isinstance(sparse.Dense(jnp.asarray(a)), LinOp)


def test_advanced_apply():
    """x = alpha * A @ b + beta * x — Ginkgo's four-argument apply."""
    a, _, _ = spd_system(24)
    A = sparse.csr_from_dense(a)
    rng = np.random.default_rng(0)
    b = rng.normal(size=24).astype(np.float32)
    x = rng.normal(size=24).astype(np.float32)
    with use_executor(XlaExecutor()):
        got = A.apply(2.0, jnp.asarray(b), -0.5, jnp.asarray(x))
    np.testing.assert_allclose(got, 2.0 * (a @ b) - 0.5 * x, rtol=1e-4, atol=1e-4)


def test_preconditioners_are_linops_with_storage():
    a, _, _ = spd_system(32)
    A = sparse.csr_from_dense(a)
    with use_executor(XlaExecutor()):
        variants = [
            solvers.identity_preconditioner,
            solvers.jacobi_preconditioner(A),
            solvers.block_jacobi_preconditioner(A, block_size=4),
            solvers.parilu_preconditioner(A),
        ]
    for M in variants:
        assert isinstance(M, LinOp), type(M)
        assert isinstance(M.storage_bytes, int)
    assert solvers.identity_preconditioner.storage_bytes == 0
    assert variants[1].storage_bytes > 0  # jacobi stores the inverse diagonal
    assert variants[3].storage_bytes > 0  # parilu stores the factors


def test_identity_preconditioner_is_identity_linop():
    assert isinstance(solvers.identity_preconditioner, Identity)
    v = jnp.arange(5, dtype=jnp.float32)
    np.testing.assert_array_equal(solvers.identity_preconditioner(v), v)


# =============================================================================
# Combinators
# =============================================================================


def test_shifted_system_solve():
    """A + sigma*I as Sum(A, ScaledIdentity) — no storage mutation of A."""
    a, _, _ = spd_system(64)
    sigma = 1.5
    A = sparse.csr_from_dense(a)
    shifted = Sum(A, ScaledIdentity(sigma, 64))
    assert shifted.shape == (64, 64)
    rng = np.random.default_rng(1)
    xstar = rng.normal(size=64).astype(np.float32)
    b = ((a + sigma * np.eye(64)) @ xstar).astype(np.float32)
    with use_executor(XlaExecutor()):
        res = solvers.cg(shifted, jnp.asarray(b), stop=STOP)
    assert bool(res.converged)
    np.testing.assert_allclose(res.x, xstar, atol=1e-3)


def test_composition_and_transpose():
    a, _, _ = spd_system(24)
    rng = np.random.default_rng(2)
    g = np.triu(rng.normal(size=(24, 24)).astype(np.float32))
    A = sparse.csr_from_dense(g)
    v = rng.normal(size=24).astype(np.float32)
    with use_executor(XlaExecutor()):
        np.testing.assert_allclose(
            Composition(A, A)(jnp.asarray(v)), g @ (g @ v), rtol=1e-3, atol=1e-3
        )
        np.testing.assert_allclose(
            Transpose(A)(jnp.asarray(v)), g.T @ v, rtol=1e-4, atol=1e-4
        )
        # A^T A via combinators — the normal-equations operator
        AtA = Composition(Transpose(A), A)
        np.testing.assert_allclose(
            AtA(jnp.asarray(v)), g.T @ (g @ v), rtol=1e-3, atol=1e-3
        )
    assert AtA.shape == (24, 24)


def test_transpose_distributes_over_combinators():
    rng = np.random.default_rng(3)
    g = rng.normal(size=(8, 8)).astype(np.float32)
    h = rng.normal(size=(8, 8)).astype(np.float32)
    A, B = sparse.csr_from_dense(g), sparse.csr_from_dense(h)
    v = rng.normal(size=8).astype(np.float32)
    with use_executor(XlaExecutor()):
        np.testing.assert_allclose(
            Transpose(Composition(A, B))(jnp.asarray(v)),
            (g @ h).T @ v, rtol=1e-3, atol=1e-3,
        )
        np.testing.assert_allclose(
            Transpose(Sum(A, B))(jnp.asarray(v)),
            (g + h).T @ v, rtol=1e-3, atol=1e-3,
        )


def test_transpose_unsupported_operator_raises():
    # every stored format is transposable now (via the CSR hub); only truly
    # matrix-free operators have no transpose to offer
    a, _, _ = spd_system(16)
    rng = np.random.default_rng(2)
    v = rng.normal(size=16).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(Transpose(sparse.ell_from_dense(a))(jnp.asarray(v))),
        a.T @ v, rtol=1e-4, atol=1e-4,
    )
    with pytest.raises(NotImplementedError, match="not transposable"):
        Transpose(MatrixFreeOp(lambda v: v, shape=(16, 16)))


def test_matrix_free_op():
    """A matrix-free operator (here: the tridiagonal stencil as pure jnp)
    drives CG without any stored matrix."""
    n = 48
    a, xstar, b = spd_system(n)

    def stencil(v):
        out = 4.0 * v
        out = out.at[1:].add(-1.0 * v[:-1]).at[:-1].add(-1.0 * v[1:])
        out = out.at[3:].add(-0.5 * v[:-3]).at[:-3].add(-0.5 * v[3:])
        return out

    A = MatrixFreeOp(stencil, shape=(n, n), dtype=jnp.float32)
    assert A.shape == (n, n)
    with use_executor(XlaExecutor()):
        res = solvers.cg(A, jnp.asarray(b), stop=STOP)
    assert bool(res.converged)
    np.testing.assert_allclose(res.x, xstar, atol=1e-3)


def test_combinator_dtype_none_when_operands_declare_none():
    """Compositions of dtype-less matrix-free operators report dtype None
    (the 'unknown' convention) instead of raising."""
    f = MatrixFreeOp(lambda v: v, shape=(4, 4))
    assert Composition(f, f).dtype is None
    assert Sum(f, f).dtype is None
    assert solvers.CgSolver(Composition(f, f)).dtype is None


def test_combinator_shape_mismatch_raises():
    a, _, _ = spd_system(8)
    A = sparse.csr_from_dense(a)
    B = sparse.csr_from_dense(np.ones((4, 8), np.float32))
    with pytest.raises(ValueError, match="shape mismatch"):
        Composition(A, B)  # (8,8) cannot follow (4,8)
    with pytest.raises(ValueError, match="mismatched shapes"):
        Sum(A, B)


# =============================================================================
# Solver factories: a generated solver IS a LinOp
# =============================================================================


def test_solver_factory_solves_via_apply():
    a, xstar, b = spd_system(48)
    A = sparse.csr_from_dense(a)
    with use_executor(XlaExecutor()):
        S = solvers.CgSolver(A, stop=STOP)
        x = S.apply(jnp.asarray(b))
        np.testing.assert_allclose(x, xstar, atol=1e-3)
        res = S.solve(jnp.asarray(b))  # the full-result face
        assert bool(res.converged)
    assert S.shape == (48, 48)


@pytest.mark.parametrize(
    "exec_cls", [ReferenceExecutor, XlaExecutor, PallasInterpretExecutor]
)
def test_solver_as_preconditioner_parity(exec_cls):
    """cg(A, b, M=GmresSolver(A, ...)) — inner-outer Krylov — must converge
    to the same answer in all three kernel spaces."""
    a, xstar, b = spd_system(48)
    A = sparse.csr_from_dense(a)
    with use_executor(exec_cls()):
        inner = solvers.GmresSolver(
            A, restart=8, stop=solvers.Stop(max_iters=8, reduction_factor=1e-2)
        )
        res = solvers.cg(A, jnp.asarray(b), M=inner,
                         stop=solvers.Stop(max_iters=100, reduction_factor=1e-6))
    assert bool(res.converged), exec_cls.__name__
    np.testing.assert_allclose(res.x, xstar, atol=1e-3)


def test_inner_outer_krylov_reduces_outer_iterations():
    a, xstar, b = spd_system(96)
    A = sparse.csr_from_dense(a)
    with use_executor(XlaExecutor()):
        plain = solvers.fcg(A, jnp.asarray(b), stop=STOP)
        inner = solvers.CgSolver(
            A, stop=solvers.Stop(max_iters=10, reduction_factor=1e-2)
        )
        nested = solvers.fcg(A, jnp.asarray(b), M=inner, stop=STOP)
    assert bool(nested.converged)
    assert int(nested.iterations) < int(plain.iterations)
    np.testing.assert_allclose(nested.x, xstar, atol=1e-3)


def test_solver_factory_resolves_string_preconditioner():
    a, xstar, b = spd_system(48)
    A = sparse.csr_from_dense(a)
    with use_executor(XlaExecutor()):
        S = solvers.CgSolver(A, stop=STOP, M="block_jacobi",
                             precond_opts={"block_size": 4})
        assert isinstance(S.M, LinOp)  # resolved at generation time
        np.testing.assert_allclose(S(jnp.asarray(b)), xstar, atol=1e-3)


# =============================================================================
# Back-compat shims (deprecated but working)
# =============================================================================


def test_linear_operator_shim_deprecated_but_working():
    a, xstar, b = spd_system(48)
    A = sparse.csr_from_dense(a)
    with pytest.warns(DeprecationWarning, match="LinearOperator is deprecated"):
        op = solvers.LinearOperator(A)
    v = jnp.asarray(np.random.default_rng(0).normal(size=48).astype(np.float32))
    with use_executor(XlaExecutor()):
        np.testing.assert_allclose(op(v), a @ np.asarray(v), rtol=1e-4, atol=1e-4)
        # and it still works as the A of a solve (it is itself a LinOp now)
        res = solvers.cg(op, jnp.asarray(b), stop=STOP)
    assert bool(res.converged)
    np.testing.assert_allclose(res.x, xstar, atol=1e-3)


def test_linear_operator_shim_wraps_callable():
    a, xstar, b = spd_system(32)
    dense = jnp.asarray(a)
    with pytest.warns(DeprecationWarning):
        op = solvers.LinearOperator(lambda v: dense @ v)
    with use_executor(XlaExecutor()):
        res = solvers.cg(op, jnp.asarray(b), stop=STOP)
    assert bool(res.converged)


def test_plain_callable_preconditioner_still_works():
    """The historical convention: M is a bare function v -> M^{-1} v."""
    a, xstar, b = spd_system(64)
    A = sparse.csr_from_dense(a)
    inv_diag = jnp.asarray(1.0 / np.diag(a).astype(np.float32))
    with use_executor(XlaExecutor()):
        res = solvers.cg(A, jnp.asarray(b), M=lambda v: inv_diag * v, stop=STOP)
    assert bool(res.converged)
    np.testing.assert_allclose(res.x, xstar, atol=1e-3)


def test_solver_threads_executor_into_preconditioner():
    """executor= passed to a solver governs the preconditioner subtree too —
    A and M must dispatch in the same kernel space."""
    a, _, b = spd_system(16)
    A = sparse.csr_from_dense(a)
    seen = []

    class Probe(LinOp):
        def _apply(self, v, executor):
            seen.append(executor)
            return v

    ex = XlaExecutor()
    solvers.cg(A, jnp.asarray(b), M=Probe(),
               stop=solvers.Stop(max_iters=2, reduction_factor=1e-10),
               executor=ex)
    assert seen and all(e is ex for e in seen), seen


def test_as_linop_coercion():
    a, _, _ = spd_system(16)
    A = sparse.csr_from_dense(a)
    assert as_linop(A) is A  # LinOps pass through untouched
    wrapped = as_linop(lambda v: v * 2.0)
    assert isinstance(wrapped, MatrixFreeOp)
    with pytest.raises(TypeError, match="cannot interpret"):
        as_linop(42)


def test_sparse_apply_accepts_composed_linops():
    """sparse.apply stays the one entry point: non-format LinOps delegate."""
    a, _, _ = spd_system(16)
    A = sparse.csr_from_dense(a)
    v = jnp.ones(16, jnp.float32)
    with use_executor(XlaExecutor()):
        got = sparse.apply(Sum(A, ScaledIdentity(2.0, 16)), v)
    np.testing.assert_allclose(got, a @ np.ones(16) + 2.0, rtol=1e-4)


def test_unregistered_format_subclass_raises():
    """A MatrixLinOp subclass missing from the dispatch table must get the
    loud TypeError, not bounce into infinite recursion."""

    class MyCsr(sparse.Csr):
        pass

    a, _, _ = spd_system(8)
    A = sparse.csr_from_dense(a)
    weird = MyCsr(A.indptr, A.indices, A.values, A.shape)
    with pytest.raises(TypeError, match="no spmv registered"):
        sparse.apply(weird, jnp.ones(8, jnp.float32))


def test_operator_sugar():
    """A + B and A @ B build Sum / Composition."""
    a, _, _ = spd_system(8)
    A = sparse.csr_from_dense(a)
    s = A + ScaledIdentity(1.0, 8)
    assert isinstance(s, Sum)
    c = A @ A
    assert isinstance(c, Composition)
    v = jnp.ones(8, jnp.float32)
    with use_executor(XlaExecutor()):
        np.testing.assert_allclose(s(v), a @ np.ones(8) + 1.0, rtol=1e-4)
