"""CG-family symmetry guard: loud failure on nonsymmetric operands.

``cg``/``fcg`` silently produce garbage on nonsymmetric systems (the Lanczos
three-term recurrence assumes A = A^T).  The seeded probe turns that into a
clear error at generation/solve time, with ``strict=False`` as the escape
hatch.  The probe is host-side numpy — it must leave **zero** footprint in
the executor dispatch log, or it would shift every launch-count pin.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import sparse, solvers
from repro.core import make_executor
from repro.solvers.common import probe_symmetry
from repro.solvers.krylov import (
    BicgstabSolver,
    CgSolver,
    FcgSolver,
    GmresSolver,
    PipelinedCgSolver,
)
from repro.sparse.gallery import convection_diffusion_2d, poisson_2d


def _gallery_csr(host):
    indptr, indices, values, shape = host
    return sparse.csr_from_arrays(indptr, indices, values, shape)


NONSYM = _gallery_csr(convection_diffusion_2d(10, peclet=5.0))
SPD = _gallery_csr(poisson_2d(10))
B = jnp.ones(100, jnp.float32)


def test_probe_classifies_gallery_matrices():
    assert probe_symmetry(NONSYM) is False
    assert probe_symmetry(SPD) is True


def test_probe_undecidable_cases_return_none():
    rect = sparse.csr_from_dense(np.ones((3, 5), np.float32))
    assert probe_symmetry(rect) is None
    assert probe_symmetry(object()) is None


@pytest.mark.parametrize("fn", [solvers.cg, solvers.fcg])
def test_cg_family_raises_on_convection_diffusion(fn):
    with pytest.raises(ValueError, match="symmetry probe"):
        fn(NONSYM, B)


@pytest.mark.parametrize("fn", [solvers.cg, solvers.fcg])
def test_error_names_the_safe_alternatives(fn):
    with pytest.raises(ValueError, match="gmres, bicgstab, or cgs"):
        fn(NONSYM, B)


@pytest.mark.parametrize("fn", [solvers.cg, solvers.fcg])
def test_strict_false_escape_hatch(fn):
    res = fn(NONSYM, B, strict=False)  # runs; result quality not claimed
    assert res.x.shape == B.shape


@pytest.mark.parametrize("cls", [CgSolver, PipelinedCgSolver, FcgSolver])
def test_factories_raise_at_generation_time(cls):
    with pytest.raises(ValueError, match="symmetry probe"):
        cls(NONSYM)
    cls(NONSYM, strict=False)  # escape hatch at generation
    cls(SPD)  # SPD operand generates cleanly


@pytest.mark.parametrize("cls", [BicgstabSolver, GmresSolver])
def test_nonsym_solvers_accept_nonsymmetric_operands(cls):
    res = cls(NONSYM).solve(B)
    assert bool(res.converged)


def test_spd_path_unaffected():
    res = solvers.cg(SPD, B)
    assert bool(res.converged)


def test_probe_skips_traced_values_under_jit():
    """Inside jit the values are tracers: the probe must pass (None), never
    raise or force a host sync."""

    @jax.jit
    def solve(values, b):
        A = sparse.Csr(values=values, indices=NONSYM.indices,
                       indptr=NONSYM.indptr, shape=NONSYM.shape)
        return solvers.gmres(A, b).x

    out = solve(NONSYM.values, B)  # gmres: no guard, traced path exercised
    assert out.shape == B.shape

    @jax.jit
    def solve_cg(values, b):
        A = sparse.Csr(values=values, indices=SPD.indices,
                       indptr=SPD.indptr, shape=SPD.shape)
        return solvers.cg(A, b).x  # guard must no-op on traced values

    out = solve_cg(SPD.values, B)
    assert out.shape == B.shape


def test_probe_leaves_no_dispatch_footprint():
    """Launch-count pins (BENCH, fused-loop tests) diff the dispatch log
    exactly — the probe must not add a single entry."""
    ex = make_executor("xla")
    ex.dispatch_log.clear()
    assert probe_symmetry(SPD) is True
    assert probe_symmetry(NONSYM) is False
    assert sum(ex.dispatch_log.values()) == 0
