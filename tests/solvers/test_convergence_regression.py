"""Solver convergence regression suite: iteration counts are pinned.

Every (solver x preconditioner) combination runs on a fixed fixture and must
converge within a *recorded* iteration bound (measured counts + 15% slack for
cross-platform float drift).  A solver or preconditioner change that degrades
convergence fails loudly here instead of silently burning iterations in the
benchmarks — Ginkgo's per-commit solver regression discipline.

Recorded counts (jax 0.4.37, f32, CPU):

    SPD (n=96):     cg / fcg
      identity 17/17   jacobi 17/17   block_jacobi 12/12
      adaptive_bj 12/12   parilu 6/6
    nonsym (n=96):  bicgstab / cgs / gmres(30)
      identity 11/10/30   jacobi 11/10/30   block_jacobi 8/7/30
      adaptive_bj 8/7/30   parilu 3/3/30
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro import sparse, solvers
from repro.core import XlaExecutor, use_executor

STOP = solvers.Stop(max_iters=500, reduction_factor=1e-6)


def spd_system(n=96, rng=None):
    rng = rng or np.random.default_rng(3)
    a = np.zeros((n, n), np.float32)
    for i in range(n):
        a[i, i] = 4.0
        if i > 0:
            a[i, i - 1] = a[i - 1, i] = -1.0
        if i > 2:
            a[i, i - 3] = a[i - 3, i] = -0.5
    x = rng.normal(size=n).astype(np.float32)
    return a, x, (a @ x).astype(np.float32)


def nonsym_system(n=96, rng=None):
    rng = rng or np.random.default_rng(4)
    a, x, _ = spd_system(n, rng)
    a = a + np.triu(rng.normal(size=(n, n)).astype(np.float32) * 0.05, 1)
    return a, x, (a @ x).astype(np.float32)


def _preconditioner(name, A):
    return {
        "identity": lambda: None,
        "jacobi": lambda: solvers.jacobi_preconditioner(A),
        "block_jacobi": lambda: solvers.block_jacobi_preconditioner(A, block_size=4),
        "adaptive_bj": lambda: solvers.block_jacobi_preconditioner(
            A, block_size=4, adaptive=True
        ),
        "parilu": lambda: solvers.parilu_preconditioner(A),
    }[name]()


def _bound(recorded: int) -> int:
    return int(np.ceil(recorded * 1.15))


# (solver, preconditioner) -> recorded iteration count
SPD_RECORDED = {
    ("cg", "identity"): 17,
    ("cg", "jacobi"): 17,
    ("cg", "block_jacobi"): 12,
    ("cg", "adaptive_bj"): 12,
    ("cg", "parilu"): 6,
    ("fcg", "identity"): 17,
    ("fcg", "jacobi"): 17,
    ("fcg", "block_jacobi"): 12,
    ("fcg", "adaptive_bj"): 12,
    ("fcg", "parilu"): 6,
}

NONSYM_RECORDED = {
    ("bicgstab", "identity"): 11,
    ("bicgstab", "jacobi"): 11,
    ("bicgstab", "block_jacobi"): 8,
    ("bicgstab", "adaptive_bj"): 8,
    ("bicgstab", "parilu"): 3,
    ("cgs", "identity"): 10,
    ("cgs", "jacobi"): 10,
    ("cgs", "block_jacobi"): 7,
    ("cgs", "adaptive_bj"): 7,
    ("cgs", "parilu"): 3,
    ("gmres", "identity"): 30,
    ("gmres", "jacobi"): 30,
    ("gmres", "block_jacobi"): 30,
    ("gmres", "adaptive_bj"): 30,
    ("gmres", "parilu"): 30,
}

SOLVERS = {
    "cg": solvers.cg,
    "fcg": solvers.fcg,
    "bicgstab": solvers.bicgstab,
    "cgs": solvers.cgs,
    "gmres": solvers.gmres,
}


@pytest.mark.parametrize("solver,precond", sorted(SPD_RECORDED))
def test_spd_convergence_regression(solver, precond):
    a, xstar, b = spd_system()
    A = sparse.csr_from_dense(a)
    with use_executor(XlaExecutor()):
        M = _preconditioner(precond, A)
        res = SOLVERS[solver](A, jnp.asarray(b), stop=STOP, M=M)
    assert bool(res.converged), f"{solver}+{precond} failed to converge"
    k, bound = int(res.iterations), _bound(SPD_RECORDED[(solver, precond)])
    assert k <= bound, (
        f"{solver}+{precond}: {k} iterations exceeds recorded bound {bound} "
        f"— convergence regression"
    )
    np.testing.assert_allclose(np.asarray(res.x), xstar, atol=2e-3)


@pytest.mark.parametrize("solver,precond", sorted(NONSYM_RECORDED))
def test_nonsym_convergence_regression(solver, precond):
    a, xstar, b = nonsym_system()
    A = sparse.csr_from_dense(a)
    with use_executor(XlaExecutor()):
        M = _preconditioner(precond, A)
        res = SOLVERS[solver](A, jnp.asarray(b), stop=STOP, M=M)
    assert bool(res.converged), f"{solver}+{precond} failed to converge"
    k, bound = int(res.iterations), _bound(NONSYM_RECORDED[(solver, precond)])
    assert k <= bound, (
        f"{solver}+{precond}: {k} iterations exceeds recorded bound {bound} "
        f"— convergence regression"
    )
    np.testing.assert_allclose(np.asarray(res.x), xstar, atol=5e-2)


# (solver, gallery matrix) -> recorded iteration count (jax 0.4.37, f32, CPU)
# gmres counts are whole restart cycles (restart=30); power-law excludes
# unpreconditioned gmres, which stalls on graph Laplacians at this tolerance
GALLERY_RECORDED = {
    ("gmres", "convdiff16_pe0p5"): 60,
    ("gmres", "convdiff16_pe2"): 60,
    ("gmres", "convdiff16_pe10"): 60,
    ("bicgstab", "convdiff16_pe0p5"): 25,
    ("bicgstab", "convdiff16_pe2"): 28,
    ("bicgstab", "convdiff16_pe10"): 23,
    ("bicgstab", "powerlaw256"): 67,
    ("cg", "powerlaw256"): 93,
}


def _gallery_system(name):
    from repro.sparse import gallery

    host = {
        "convdiff16_pe0p5": lambda: gallery.convection_diffusion_2d(
            16, peclet=0.5, scheme="centered"),
        "convdiff16_pe2": lambda: gallery.convection_diffusion_2d(
            16, peclet=2.0, scheme="upwind"),
        "convdiff16_pe10": lambda: gallery.convection_diffusion_2d(
            16, peclet=10.0, scheme="upwind"),
        "powerlaw256": lambda: gallery.power_law_laplacian(256, seed=4),
    }[name]()
    indptr, indices, values, shape = host
    a = np.zeros(shape, np.float32)
    rows = np.repeat(np.arange(shape[0]), np.diff(indptr))
    a[rows, indices] = values
    b = np.random.default_rng(0).normal(size=shape[0]).astype(np.float32)
    return a, sparse.csr_from_arrays(indptr, indices, values, shape), b


@pytest.mark.parametrize("solver,matrix", sorted(GALLERY_RECORDED))
def test_gallery_convergence_regression(solver, matrix):
    """The realistic corpus is held to the same pinned-iteration discipline
    as the synthetic fixtures, across Péclet regimes and the power-law
    degree distribution."""
    a, A, b = _gallery_system(matrix)
    with use_executor(XlaExecutor()):
        res = SOLVERS[solver](A, jnp.asarray(b), stop=STOP)
    assert bool(res.converged), f"{solver} on {matrix} failed to converge"
    k, bound = int(res.iterations), _bound(GALLERY_RECORDED[(solver, matrix)])
    assert k <= bound, (
        f"{solver} on {matrix}: {k} iterations exceeds recorded bound {bound}"
        f" — convergence regression"
    )
    rel = np.linalg.norm(b - a @ np.asarray(res.x)) / np.linalg.norm(b)
    assert rel <= 1e-4, f"{solver} on {matrix}: true residual {rel:.2e}"


def test_preconditioner_ordering_invariants():
    """Stronger preconditioners may never lose to weaker ones on the SPD
    fixture: parilu <= block_jacobi <= jacobi <= identity (iterations)."""
    a, _, b = spd_system()
    A = sparse.csr_from_dense(a)
    with use_executor(XlaExecutor()):
        iters = {
            name: int(
                solvers.cg(A, jnp.asarray(b), stop=STOP, M=_preconditioner(name, A)).iterations
            )
            for name in ("identity", "jacobi", "block_jacobi", "parilu")
        }
    assert iters["parilu"] <= iters["block_jacobi"] <= iters["jacobi"] <= iters["identity"], iters


def test_string_preconditioner_path_matches_callable():
    """The M=<kind-name> path (how adaptive threads through the solvers)
    resolves to the same preconditioner the explicit factory builds."""
    a, _, b = spd_system()
    A = sparse.csr_from_dense(a)
    with use_executor(XlaExecutor()):
        via_str = solvers.cg(
            A, jnp.asarray(b), stop=STOP, M="block_jacobi",
            precond_opts={"block_size": 4, "adaptive": True},
        )
        via_call = solvers.cg(
            A, jnp.asarray(b), stop=STOP,
            M=solvers.block_jacobi_preconditioner(A, block_size=4, adaptive=True),
        )
    assert int(via_str.iterations) == int(via_call.iterations)
    np.testing.assert_allclose(np.asarray(via_str.x), np.asarray(via_call.x), atol=1e-5)
