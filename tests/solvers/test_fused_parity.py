"""Fallback-parity contract of the fused Krylov paths.

On the reference/xla kernel spaces the fused ops are the literal unfused
composition, so ``fused=True`` and ``fused=False`` must be BITWISE identical
— same iterate sequence, same iteration count, same solution bits.  These
tests pin that contract plus the launch-count claim (fused CG does its
per-iteration reduction work in 2 kernel launches, the portable loop in ≥ 5)
and the capability probe's graceful degradation.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import sparse
from repro.core.executor import make_executor
from repro.core.linop import MatrixFreeOp
from repro.solvers import PipelinedCgSolver, Stop, bicgstab, cg
from repro.sparse import ops as blas


def _spd(n=80, density=0.08, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    d = rng.standard_normal((n, n)) * (rng.random((n, n)) < density)
    s = (d @ d.T + n * np.eye(n)).astype(dtype)
    b = rng.standard_normal(n).astype(dtype)
    return s, b


ST = Stop(reduction_factor=1e-8, max_iters=500)


@pytest.mark.parametrize("exec_kind", ("reference", "xla"))
@pytest.mark.parametrize("fmt", ("csr", "ell"))
def test_cg_fused_off_on_bitwise(exec_kind, fmt):
    s, b = _spd()
    build = {"csr": sparse.csr_from_dense, "ell": sparse.ell_from_dense}[fmt]
    A = build(s)
    ex = make_executor(exec_kind)
    on = cg(A, jnp.asarray(b), stop=ST, executor=ex, fused=True)
    off = cg(A, jnp.asarray(b), stop=ST, executor=ex, fused=False)
    assert int(on.iterations) == int(off.iterations)
    assert bool(on.converged) and bool(off.converged)
    # bitwise, not approximately: the fused ops ARE the unfused composition
    # in these spaces
    assert bool(jnp.all(on.x == off.x))
    np.testing.assert_allclose(
        np.asarray(on.x, np.float64), np.asarray(off.x, np.float64),
        rtol=1e-10,
    )


@pytest.mark.parametrize("M", (None, "jacobi"))
def test_cg_fused_preconditioned_bitwise(M):
    s, b = _spd(seed=5)
    A = sparse.csr_from_dense(s)
    ex = make_executor("xla")
    on = cg(A, jnp.asarray(b), stop=ST, executor=ex, fused=True, M=M)
    off = cg(A, jnp.asarray(b), stop=ST, executor=ex, fused=False, M=M)
    assert int(on.iterations) == int(off.iterations)
    assert bool(jnp.all(on.x == off.x))


def test_bicgstab_fused_off_on_bitwise():
    rng = np.random.default_rng(7)
    n = 70
    a = rng.standard_normal((n, n)) * (rng.random((n, n)) < 0.1)
    a = (a + n * np.eye(n)).astype(np.float32)  # diagonally dominant
    b = rng.standard_normal(n).astype(np.float32)
    A = sparse.csr_from_dense(a)
    ex = make_executor("xla")
    on = bicgstab(A, jnp.asarray(b), stop=ST, executor=ex, fused=True)
    off = bicgstab(A, jnp.asarray(b), stop=ST, executor=ex, fused=False)
    assert int(on.iterations) == int(off.iterations)
    assert bool(jnp.all(on.x == off.x))


def test_fused_cg_reduction_launch_count():
    """The perf claim behind the fused path: with identity M, the CG loop
    body performs its reduction work in exactly 2 op launches (spmv_dot +
    axpy_norm) where the portable loop needs 5+ (spmv, 2 dots, norm, plus
    the reduction-free axpys).  ``lax.while_loop`` traces the body once, so
    dispatch-log deltas over known init counts are per-iteration counts."""
    s, b = _spd(seed=2)
    A = sparse.csr_from_dense(s)
    ex = make_executor("xla")
    ex.dispatch_log.clear()
    cg(A, jnp.asarray(b), stop=ST, executor=ex, fused=True)
    log = dict(ex.dispatch_log)
    # fused ops appear ONLY in the loop body
    assert log["spmv_dot_csr"] == 1
    assert log["axpy_norm"] == 1
    # with identity M the body carries no standalone dot (init rz is the one)
    assert log["blas_dot"] == 1

    ex.dispatch_log.clear()
    cg(A, jnp.asarray(b), stop=ST, executor=ex, fused=False)
    log = dict(ex.dispatch_log)
    # init: 1 spmv, 1 dot, 2 norms -> body counts by subtraction
    body_launches = (
        (log["spmv_csr"] - 1)
        + (log["blas_dot"] - 1)
        + (log["blas_norm2"] - 2)
        + log["blas_axpy"]
    )
    assert body_launches >= 5


def test_capability_probe_graceful_fallback():
    """Matrix-free operators have no fused SpMV: fused=True must degrade to
    the portable loop (identical result), never raise."""
    s, b = _spd(seed=3)
    A = sparse.csr_from_dense(s)
    ex = make_executor("xla")
    sj = jnp.asarray(s)
    free = MatrixFreeOp(lambda v: sj @ v, shape=s.shape, dtype=s.dtype)
    assert not blas.has_fused_ops(free, executor=ex)
    assert blas.has_fused_ops(A, executor=ex)
    got = cg(free, jnp.asarray(b), stop=ST, executor=ex, fused=True)
    want = cg(free, jnp.asarray(b), stop=ST, executor=ex, fused=False)
    assert int(got.iterations) == int(want.iterations)
    assert bool(jnp.all(got.x == want.x))


def test_pipelined_cg_matches_classic():
    """Pipelining reassociates the recurrences — iteration counts may drift
    by a couple of steps, the solution agrees to solver tolerance.  (The
    tolerance is the f32-attainable 1e-6: the pipelined recurrence residual
    stagnates earlier than classic CG's, the known accuracy trade of the
    method, so tighter stops belong to f64 runs.)"""
    s, b = _spd(seed=11)
    A = sparse.csr_from_dense(s)
    ex = make_executor("xla")
    st6 = Stop(reduction_factor=1e-6, max_iters=500)
    classic = cg(A, jnp.asarray(b), stop=st6, executor=ex, fused=False)
    piped = cg(A, jnp.asarray(b), stop=st6, executor=ex, pipeline=True)
    assert bool(piped.converged)
    assert abs(int(piped.iterations) - int(classic.iterations)) <= 2
    xd = np.linalg.solve(s.astype(np.float64), b.astype(np.float64))
    np.testing.assert_allclose(np.asarray(piped.x, np.float64), xd,
                               rtol=1e-4, atol=1e-4)


def test_pipelined_cg_solver_linop():
    s, b = _spd(seed=13)
    A = sparse.csr_from_dense(s)
    ex = make_executor("xla")
    solver = PipelinedCgSolver(
        A, stop=Stop(reduction_factor=1e-6, max_iters=500), executor=ex
    )
    res = solver.solve(jnp.asarray(b))
    assert bool(res.converged)
    # the LinOp face composes like any operator
    x = solver.apply(jnp.asarray(b))
    assert bool(jnp.all(x == res.x))


def test_pipelined_cg_single_batched_reduction():
    """One dot_batch (= one fused reduction) per iteration, no standalone
    dot/norm launches inside the loop body."""
    s, b = _spd(seed=17)
    A = sparse.csr_from_dense(s)
    ex = make_executor("xla")
    ex.dispatch_log.clear()
    cg(A, jnp.asarray(b), stop=ST, executor=ex, pipeline=True)
    log = dict(ex.dispatch_log)
    # init: norm2(b), dot_batch(3); body trace: dot_batch(3) -> 6 total dots,
    # and no norm2 dispatch from the body (the stop norm is sqrt of the
    # batched r·r)
    assert log["blas_dot"] == 6
    assert log["blas_norm2"] == 1
