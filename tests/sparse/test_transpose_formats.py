"""Transpose conformance: every format, degenerate rows, executor threading.

``Transpose(A).apply(x)`` must equal dense ``A.T @ x`` for every storage
format — including matrices with empty rows (which become empty *columns*
under transpose and vice versa), the degenerate the ELL/SELL-P padding paths
historically mishandled.  The executor-threading pin guards the implicit
layer's backward pass: the transposed operator must dispatch through the same
``Executor.launch_config`` path (same dispatch log) as the forward operator.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from _hyp_compat import given, settings, st

from repro import sparse
from repro.core import Composition, make_executor
from repro.core.linop import Transpose
from repro.solvers.common import ScalarJacobi

FORMATS = ("coo", "csr", "ell", "sellp", "dense")

BUILD = {
    "coo": sparse.coo_from_dense,
    "csr": sparse.csr_from_dense,
    "ell": sparse.ell_from_dense,
    "sellp": sparse.sellp_from_dense,
    "dense": lambda a: sparse.Dense(jnp.asarray(a)),
}


def _pattern(m, n, density, seed, empty_rows=0, empty_cols=0):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(m, n)).astype(np.float32)
    mask = rng.random((m, n)) < density
    a = np.where(mask, a, 0.0)
    for i in rng.choice(m, size=min(empty_rows, m), replace=False):
        a[i, :] = 0.0
    for j in rng.choice(n, size=min(empty_cols, n), replace=False):
        a[:, j] = 0.0
    return a


@pytest.mark.parametrize("fmt", FORMATS)
@settings(max_examples=8)
@given(
    m=st.integers(1, 40),
    n=st.integers(1, 40),
    density=st.floats(0.02, 0.9),
    seed=st.integers(0, 10_000),
    empty_rows=st.integers(0, 3),
    empty_cols=st.integers(0, 3),
)
def test_transpose_matches_dense(fmt, m, n, density, seed, empty_rows,
                                 empty_cols):
    a = _pattern(m, n, density, seed, empty_rows, empty_cols)
    x = np.random.default_rng(seed + 1).normal(size=m).astype(np.float32)
    A = BUILD[fmt](a)
    got = np.asarray(Transpose(A).apply(jnp.asarray(x)))
    np.testing.assert_allclose(got, a.T @ x, rtol=1e-4, atol=1e-5,
                               err_msg=f"Transpose({fmt}) != dense A.T @ x")


@pytest.mark.parametrize("fmt", FORMATS)
def test_transpose_preserves_format(fmt):
    a = _pattern(9, 7, 0.4, 0)
    A = BUILD[fmt](a)
    At = A.transpose()
    assert type(At) is type(A), f"{fmt}: transpose changed format to {type(At)}"
    assert At.shape == (7, 9)


def test_transpose_all_zero_matrix():
    a = np.zeros((5, 3), np.float32)
    x = np.ones(5, np.float32)
    for fmt in FORMATS:
        got = np.asarray(Transpose(BUILD[fmt](a)).apply(jnp.asarray(x)))
        np.testing.assert_array_equal(got, np.zeros(3, np.float32))


def test_sellp_transpose_keeps_slice_geometry():
    a = _pattern(20, 20, 0.3, 2)
    A = sparse.sellp_from_dense(a)
    At = A.transpose()
    assert At.slice_size == A.slice_size
    assert At.stride_factor == A.stride_factor


def test_csr_transpose_traced_values_under_jit():
    """Pattern-static differentiable transpose: structure stays host-side
    concrete while values are traced (the implicit-layer backward)."""
    import jax

    a = _pattern(12, 12, 0.4, 3)
    A = sparse.csr_from_dense(a)
    x = jnp.asarray(np.random.default_rng(4).normal(size=12).astype(np.float32))

    @jax.jit
    def f(values, xv):
        B = sparse.Csr(values=values, indices=A.indices, indptr=A.indptr,
                       shape=A.shape)
        return Transpose(B).apply(xv)

    got = np.asarray(f(A.values, x))
    np.testing.assert_allclose(got, a.T @ np.asarray(x), rtol=1e-4, atol=1e-5)


def test_transpose_inherits_executor_and_dispatch_path():
    """Satellite pin: ``Transpose(Composition(...))`` must dispatch through
    the *same* executor as the forward operator — the backward solve of the
    implicit layer relies on forward/adjoint landing in one kernel space."""
    a = _pattern(10, 10, 0.5, 5)
    ex = make_executor("reference")
    A = sparse.csr_from_dense(a)
    M = ScalarJacobi(jnp.ones(10, jnp.float32) * 0.5)
    comp = Composition(M, A, executor=ex)
    t = Transpose(comp)
    assert t.executor is ex, "Transpose dropped the composed operator's executor"

    x = jnp.asarray(np.random.default_rng(6).normal(size=10).astype(np.float32))
    ex.dispatch_log.clear()
    comp.apply(x)
    fwd_log = dict(ex.dispatch_log)
    ex.dispatch_log.clear()
    t.apply(x)
    bwd_log = dict(ex.dispatch_log)
    assert sum(fwd_log.values()) > 0, "forward apply dispatched nothing"
    assert bwd_log.keys() == fwd_log.keys(), (
        f"transpose dispatched {sorted(bwd_log)} but forward dispatched "
        f"{sorted(fwd_log)} — executor threading lost"
    )
    assert bwd_log == fwd_log

    # explicit executor= still wins over inheritance
    ex2 = make_executor("reference")
    assert Transpose(comp, executor=ex2).executor is ex2

    # numerics: the composed transpose equals the dense adjoint
    dense = 0.5 * a  # Composition(M, A) = M @ A with M = 0.5 I
    got = np.asarray(t.apply(x))
    np.testing.assert_allclose(got, dense.T @ np.asarray(x), rtol=1e-4,
                               atol=1e-5)
