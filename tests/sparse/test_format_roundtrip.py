"""Format-conversion round-trips: Csr <-> Ell <-> Sellp <-> Coo <-> Dense.

Ginkgo's ``ConvertibleTo`` contract: converting between any two formats must
preserve the matrix — the stored layout changes, the operator does not.  The
suite walks conversion chains over hypothesis-generated patterns (via the
``_hyp_compat`` shim when hypothesis is absent) and checks, at every hop,

* ``to_dense`` reproduces the construction input, and
* ``apply`` parity: the converted operator computes the same SpMV,

including the degenerate patterns that historically break padded formats:
empty rows, single-column matrices, and the all-zero matrix.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from _hyp_compat import given, settings, st

from repro import sparse
from repro.core import ReferenceExecutor, XlaExecutor, use_executor

FORMATS = ("csr", "ell", "sellp", "coo", "dense")

BUILD = {
    "coo": sparse.coo_from_dense,
    "csr": sparse.csr_from_dense,
    "ell": sparse.ell_from_dense,
    "sellp": sparse.sellp_from_dense,
    "dense": lambda a: sparse.Dense(jnp.asarray(a)),
}

#: full cycle touching every format, plus the reverse orientation
CHAINS = (
    ("csr", "ell", "sellp", "coo", "dense", "csr"),
    ("dense", "coo", "sellp", "ell", "csr", "dense"),
)


def _pattern(m, n, density, seed):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(m, n)).astype(np.float32)
    return np.where(rng.random((m, n)) < density, a, 0.0)


def _check_chain(a, chain, x):
    """Walk ``chain``, asserting densify + apply parity at every hop."""
    want = a @ x
    A = BUILD[chain[0]](a)
    with use_executor(XlaExecutor()):
        for hop in chain[1:]:
            A = sparse.convert(A, hop)
            assert A.shape == a.shape, f"{hop}: shape drifted to {A.shape}"
            assert A.dtype == a.dtype, f"{hop}: dtype drifted to {A.dtype}"
            with use_executor(ReferenceExecutor()):
                np.testing.assert_allclose(
                    np.asarray(sparse.to_dense(A)), a, atol=1e-6,
                    err_msg=f"to_dense after converting to {hop}",
                )
            got = sparse.apply(A, jnp.asarray(x))
            np.testing.assert_allclose(
                np.asarray(got), want, rtol=1e-3, atol=1e-4,
                err_msg=f"apply parity after converting to {hop}",
            )


@pytest.mark.parametrize("chain", CHAINS, ids=lambda c: "->".join(c))
@settings(max_examples=6)
@given(
    m=st.integers(1, 40),
    n=st.integers(1, 40),
    density=st.floats(0.02, 0.9),
    seed=st.integers(0, 10_000),
)
def test_roundtrip_chain_property(chain, m, n, density, seed):
    a = _pattern(m, n, density, seed)
    x = np.random.default_rng(seed + 1).normal(size=(n,)).astype(np.float32)
    _check_chain(a, chain, x)


@settings(max_examples=6)
@given(
    src=st.sampled_from(FORMATS),
    dst=st.sampled_from(FORMATS),
    seed=st.integers(0, 10_000),
)
def test_pairwise_conversion_property(src, dst, seed):
    """Every ordered (src, dst) pair converts losslessly."""
    a = _pattern(17, 23, 0.2, seed)
    x = np.random.default_rng(seed + 1).normal(size=(23,)).astype(np.float32)
    _check_chain(a, (src, dst, src), x)


def test_roundtrip_empty_rows():
    """Rows with no entries survive every conversion (the ELL/SELL-P padding
    and the CSR searchsorted row-id path are both easy to get wrong here)."""
    a = np.zeros((16, 12), np.float32)
    a[3, 5] = 2.0
    a[10, 0] = -1.5  # a *real* column-0 entry, the padding look-alike
    x = np.random.default_rng(0).normal(size=12).astype(np.float32)
    for chain in CHAINS:
        _check_chain(a, chain, x)


def test_roundtrip_single_column():
    """n == 1: every stored entry points at column 0, indistinguishable from
    the padding convention by column alone."""
    a = np.zeros((9, 1), np.float32)
    a[[0, 4, 8], 0] = [1.0, -2.0, 3.0]
    x = np.asarray([0.5], np.float32)
    for chain in CHAINS:
        _check_chain(a, chain, x)


def test_roundtrip_single_row_and_all_zero():
    x3 = np.random.default_rng(1).normal(size=3).astype(np.float32)
    _check_chain(np.asarray([[1.0, 0.0, 2.0]], np.float32), CHAINS[0], x3)
    # all-zero matrix: nnz == 0 everywhere, padded formats keep min-width rows
    _check_chain(np.zeros((5, 7), np.float32), CHAINS[0],
                 np.random.default_rng(2).normal(size=7).astype(np.float32))


def test_convert_preserves_sellp_kwargs():
    a = _pattern(20, 20, 0.3, 5)
    A = sparse.convert(sparse.csr_from_dense(a), "sellp", slice_size=4,
                       stride_factor=2)
    assert A.slice_size == 4 and A.stride_factor == 2
    with use_executor(ReferenceExecutor()):
        np.testing.assert_allclose(np.asarray(sparse.to_dense(A)), a, atol=1e-6)


def test_convert_same_format_is_identity():
    a = _pattern(8, 8, 0.4, 6)
    A = sparse.csr_from_dense(a)
    assert sparse.convert(A, "csr") is A


def test_convert_unknown_target_raises():
    A = sparse.csr_from_dense(np.eye(3, dtype=np.float32))
    with pytest.raises(KeyError, match="unknown format"):
        sparse.convert(A, "hybrid")
