"""Sparse formats: construction, conversions, SpMV vs dense — all executors."""

import numpy as np
import jax.numpy as jnp
import pytest
from _hyp_compat import given, st

from repro import sparse
from repro.core import PallasInterpretExecutor, ReferenceExecutor, XlaExecutor, use_executor


def random_sparse(rng, m, n, density=0.15, skew=False):
    a = rng.normal(size=(m, n)).astype(np.float32)
    mask = rng.random((m, n)) < density
    if skew:  # heavy rows every 7th (exercises SELL-P raggedness)
        mask[::7] = rng.random((len(mask[::7]), n)) < min(6 * density, 0.9)
    return np.where(mask, a, 0.0)


EXECUTORS = [ReferenceExecutor, XlaExecutor, PallasInterpretExecutor]
FORMATS = ["coo", "csr", "ell", "sellp", "dense"]


def build(fmt, a):
    return {
        "coo": sparse.coo_from_dense,
        "csr": sparse.csr_from_dense,
        "ell": sparse.ell_from_dense,
        "sellp": sparse.sellp_from_dense,
        "dense": lambda x: sparse.Dense(jnp.asarray(x)),
    }[fmt](a)


@pytest.mark.parametrize("fmt", FORMATS)
@pytest.mark.parametrize("exec_cls", EXECUTORS)
def test_spmv_vs_dense(rng, fmt, exec_cls):
    a = random_sparse(rng, 57, 43, skew=True)
    x = rng.normal(size=(43,)).astype(np.float32)
    A = build(fmt, a)
    with use_executor(exec_cls()):
        got = sparse.apply(A, jnp.asarray(x))
    np.testing.assert_allclose(got, a @ x, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("fmt", FORMATS)
def test_to_dense_roundtrip(rng, fmt):
    a = random_sparse(rng, 23, 31)
    A = build(fmt, a)
    with use_executor(ReferenceExecutor()):
        np.testing.assert_allclose(sparse.to_dense(A), a, atol=1e-6)


@given(
    m=st.integers(1, 40),
    n=st.integers(1, 40),
    density=st.floats(0.01, 0.9),
    seed=st.integers(0, 1000),
)
def test_formats_agree_property(m, n, density, seed):
    """All formats compute the same SpMV for arbitrary shapes/sparsity."""
    rng = np.random.default_rng(seed)
    a = random_sparse(rng, m, n, density)
    x = rng.normal(size=(n,)).astype(np.float32)
    want = a @ x
    with use_executor(XlaExecutor()):
        for fmt in ("coo", "csr", "ell", "sellp"):
            got = sparse.apply(build(fmt, a), jnp.asarray(x))
            np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


def test_empty_rows_and_cols(rng):
    a = np.zeros((16, 16), np.float32)
    a[3, 5] = 2.0
    x = rng.normal(size=(16,)).astype(np.float32)
    with use_executor(XlaExecutor()):
        for fmt in ("coo", "csr", "ell", "sellp"):
            got = sparse.apply(build(fmt, a), jnp.asarray(x))
            np.testing.assert_allclose(got, a @ x, atol=1e-5)


def test_sellp_slice_layout(rng):
    """SELL-P invariants: slice_sets cumsum of padded widths, stride aligned."""
    a = random_sparse(rng, 37, 20, skew=True)
    A = sparse.sellp_from_dense(a, slice_size=8, stride_factor=4)
    ss = np.asarray(A.slice_sets)
    sc = np.asarray(A.slice_cols)
    assert (np.diff(ss) == sc).all()
    assert (sc % 4 == 0).all()
    assert A.values.shape[0] == ss[-1] * A.slice_size
    assert A.max_slice_cols == sc.max()


def test_multi_rhs_spmv(rng):
    a = random_sparse(rng, 20, 15)
    X = rng.normal(size=(15, 3)).astype(np.float32)
    with use_executor(XlaExecutor()):
        for fmt in ("coo", "csr", "ell"):
            got = sparse.apply(build(fmt, a), jnp.asarray(X))
            np.testing.assert_allclose(got, a @ X, rtol=1e-4, atol=1e-4)
