"""Gallery generators: SPD structure, stencil correctness, CSR validity."""

import numpy as np
import pytest

from repro.sparse.gallery import (
    BANDED_OFFSETS,
    anisotropic_2d,
    poisson_2d,
    poisson_3d,
    spd_banded,
)


def _to_dense(indptr, indices, values, shape):
    a = np.zeros(shape, values.dtype)
    for i in range(shape[0]):
        for t in range(indptr[i], indptr[i + 1]):
            a[i, indices[t]] = values[t]
    return a


def _check_csr(indptr, indices, values, shape):
    assert indptr[0] == 0 and indptr[-1] == indices.size == values.size
    assert np.all(np.diff(indptr) >= 0)
    for i in range(shape[0]):
        row = indices[indptr[i]: indptr[i + 1]]
        assert np.all(np.diff(row) > 0)


@pytest.mark.parametrize("gen,args", [
    (poisson_2d, (6,)),
    (poisson_3d, (4,)),
    (anisotropic_2d, (6, 0.01)),
])
def test_gallery_spd(gen, args):
    indptr, indices, values, shape = gen(*args)
    _check_csr(indptr, indices, values, shape)
    a = _to_dense(indptr, indices, values, shape)
    np.testing.assert_allclose(a, a.T, atol=0)
    w = np.linalg.eigvalsh(a.astype(np.float64))
    assert w.min() > 0, f"{gen.__name__} not positive definite: {w.min()}"


def test_poisson_2d_stencil():
    indptr, indices, values, shape = poisson_2d(4)
    assert shape == (16, 16)
    a = _to_dense(indptr, indices, values, shape)
    assert np.all(np.diag(a) == 4.0)
    # interior point (1,1) -> row 5 has 4 off-diagonal -1 neighbours
    row = a[5]
    assert row[5] == 4.0
    np.testing.assert_array_equal(
        np.sort(np.flatnonzero(row == -1.0)), [1, 4, 6, 9]
    )


def test_spd_banded_offsets():
    rng = np.random.default_rng(0)
    indptr, indices, values, shape = spd_banded(32, BANDED_OFFSETS[1], 0.5, rng)
    _check_csr(indptr, indices, values, shape)
    a = _to_dense(indptr, indices, values, shape)
    np.testing.assert_allclose(a, a.T, atol=1e-6)
    assert np.linalg.eigvalsh(a.astype(np.float64)).min() > 0
    # band structure: entries only on the requested offsets
    nz_off = {int(j - i) for i, j in zip(*np.nonzero(a))}
    want = {0} | {o for o in BANDED_OFFSETS[1]} | {-o for o in BANDED_OFFSETS[1]}
    assert nz_off <= want


def test_spd_banded_deterministic_pattern():
    """Same rng seed -> same pattern and values (the serve gallery relies on
    replayable patterns for its cache-hit traffic)."""
    a = spd_banded(24, BANDED_OFFSETS[0], 0.3, np.random.default_rng(7))
    b = spd_banded(24, BANDED_OFFSETS[0], 0.3, np.random.default_rng(7))
    for x, y in zip(a[:3], b[:3]):
        np.testing.assert_array_equal(x, y)


# -- PR-10 corpus: convection-diffusion + power-law Laplacians ----------------

def test_convection_diffusion_is_nonsymmetric_and_scales_with_peclet():
    from repro.sparse.gallery import convection_diffusion_2d

    asym = {}
    for pe in (0.1, 1.5, 10.0):
        indptr, indices, values, shape = convection_diffusion_2d(8, peclet=pe)
        _check_csr(indptr, indices, values, shape)
        a = _to_dense(indptr, indices, values, shape)
        asym[pe] = np.linalg.norm(a - a.T)
        assert asym[pe] > 0, f"Pe={pe}: matrix is symmetric"
    assert asym[0.1] < asym[1.5] < asym[10.0], (
        f"asymmetry must grow with Péclet: {asym}"
    )


@pytest.mark.parametrize("scheme", ["upwind", "centered"])
def test_convection_diffusion_eigenvalues_in_right_half_plane(scheme):
    """Both discretizations must stay nonsingular/convergent-friendly: every
    eigenvalue has positive real part (upwind additionally keeps an
    M-matrix-style dominant diagonal)."""
    from repro.sparse.gallery import convection_diffusion_2d

    indptr, indices, values, shape = convection_diffusion_2d(
        8, peclet=5.0, scheme=scheme
    )
    a = _to_dense(indptr, indices, values, shape).astype(np.float64)
    w = np.linalg.eigvals(a)
    assert w.real.min() > 0, f"{scheme}: eigenvalue with Re <= 0"


def test_convection_diffusion_rejects_unknown_scheme():
    from repro.sparse.gallery import convection_diffusion_2d

    with pytest.raises(ValueError):
        convection_diffusion_2d(4, scheme="quick")


def test_power_law_laplacian_spd_and_heavy_tailed():
    from repro.sparse.gallery import power_law_laplacian

    indptr, indices, values, shape = power_law_laplacian(200, shift=1e-2, seed=0)
    _check_csr(indptr, indices, values, shape)
    a = _to_dense(indptr, indices, values, shape).astype(np.float64)
    np.testing.assert_allclose(a, a.T, atol=1e-6)
    w = np.linalg.eigvalsh(a)
    # shifted graph Laplacian: SPD with smallest eigenvalue ~= shift
    assert w.min() > 0
    np.testing.assert_allclose(w.min(), 1e-2, rtol=0.2)
    # degree spread: the power-law tail must produce hubs well above the
    # typical degree (a uniform-degree graph would fail this)
    deg = np.diff(indptr) - 1  # minus the diagonal entry
    assert deg.max() >= 4 * max(int(np.median(deg)), 1), (
        f"no heavy tail: max degree {deg.max()}, median {np.median(deg)}"
    )


def test_power_law_laplacian_deterministic_per_seed():
    from repro.sparse.gallery import power_law_laplacian

    a = power_law_laplacian(100, seed=3)
    b = power_law_laplacian(100, seed=3)
    for x, y in zip(a[:3], b[:3]):
        np.testing.assert_array_equal(x, y)
    c = power_law_laplacian(100, seed=4)
    assert not np.array_equal(a[1], c[1])


def test_power_law_laplacian_row_sums_equal_shift():
    """L = D - A + shift*I: every row sums to shift (f32 accumulation)."""
    from repro.sparse.gallery import power_law_laplacian

    indptr, indices, values, shape = power_law_laplacian(150, shift=0.5, seed=1)
    a = _to_dense(indptr, indices, values, shape)
    np.testing.assert_allclose(a.sum(axis=1), 0.5, atol=1e-4)
