"""Gallery generators: SPD structure, stencil correctness, CSR validity."""

import numpy as np
import pytest

from repro.sparse.gallery import (
    BANDED_OFFSETS,
    anisotropic_2d,
    poisson_2d,
    poisson_3d,
    spd_banded,
)


def _to_dense(indptr, indices, values, shape):
    a = np.zeros(shape, values.dtype)
    for i in range(shape[0]):
        for t in range(indptr[i], indptr[i + 1]):
            a[i, indices[t]] = values[t]
    return a


def _check_csr(indptr, indices, values, shape):
    assert indptr[0] == 0 and indptr[-1] == indices.size == values.size
    assert np.all(np.diff(indptr) >= 0)
    for i in range(shape[0]):
        row = indices[indptr[i]: indptr[i + 1]]
        assert np.all(np.diff(row) > 0)


@pytest.mark.parametrize("gen,args", [
    (poisson_2d, (6,)),
    (poisson_3d, (4,)),
    (anisotropic_2d, (6, 0.01)),
])
def test_gallery_spd(gen, args):
    indptr, indices, values, shape = gen(*args)
    _check_csr(indptr, indices, values, shape)
    a = _to_dense(indptr, indices, values, shape)
    np.testing.assert_allclose(a, a.T, atol=0)
    w = np.linalg.eigvalsh(a.astype(np.float64))
    assert w.min() > 0, f"{gen.__name__} not positive definite: {w.min()}"


def test_poisson_2d_stencil():
    indptr, indices, values, shape = poisson_2d(4)
    assert shape == (16, 16)
    a = _to_dense(indptr, indices, values, shape)
    assert np.all(np.diag(a) == 4.0)
    # interior point (1,1) -> row 5 has 4 off-diagonal -1 neighbours
    row = a[5]
    assert row[5] == 4.0
    np.testing.assert_array_equal(
        np.sort(np.flatnonzero(row == -1.0)), [1, 4, 6, 9]
    )


def test_spd_banded_offsets():
    rng = np.random.default_rng(0)
    indptr, indices, values, shape = spd_banded(32, BANDED_OFFSETS[1], 0.5, rng)
    _check_csr(indptr, indices, values, shape)
    a = _to_dense(indptr, indices, values, shape)
    np.testing.assert_allclose(a, a.T, atol=1e-6)
    assert np.linalg.eigvalsh(a.astype(np.float64)).min() > 0
    # band structure: entries only on the requested offsets
    nz_off = {int(j - i) for i, j in zip(*np.nonzero(a))}
    want = {0} | {o for o in BANDED_OFFSETS[1]} | {-o for o in BANDED_OFFSETS[1]}
    assert nz_off <= want


def test_spd_banded_deterministic_pattern():
    """Same rng seed -> same pattern and values (the serve gallery relies on
    replayable patterns for its cache-hit traffic)."""
    a = spd_banded(24, BANDED_OFFSETS[0], 0.3, np.random.default_rng(7))
    b = spd_banded(24, BANDED_OFFSETS[0], 0.3, np.random.default_rng(7))
    for x, y in zip(a[:3], b[:3]):
        np.testing.assert_array_equal(x, y)
