"""SpGEMM / sparse-transpose unit tests: degenerates, invariants, algebra.

The conformance matrix in ``tests/conformance`` pins cross-executor
agreement; this module pins the *semantics* of the operation itself against
dense numpy oracles — including the degenerate structures SpGEMM is most
likely to mishandle (empty rows, rows whose products cancel, rectangular
operands) and the output invariants every space must share bit-for-bit
(column-sorted, duplicate-free rows; pattern a pure function of the operand
patterns).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from _hyp_compat import given, settings, st

from repro import sparse
from repro.core import make_executor
from repro.sparse import Csr, csr_from_arrays, csr_from_dense, spgemm, sptranspose


def _rand_sparse(m, n, density, seed):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(m, n)).astype(np.float32)
    return np.where(rng.random((m, n)) < density, a, 0.0)


def _dense(C: Csr) -> np.ndarray:
    return np.asarray(sparse.to_dense(C, executor=make_executor("reference")))


def _assert_csr_invariants(C: Csr):
    """Column-sorted, duplicate-free rows; indptr consistent with indices."""
    indptr = np.asarray(C.indptr)
    indices = np.asarray(C.indices)
    assert indptr[0] == 0 and indptr[-1] == indices.size
    assert np.all(np.diff(indptr) >= 0)
    for i in range(C.shape[0]):
        row = indices[indptr[i]: indptr[i + 1]]
        assert np.all(np.diff(row) > 0), f"row {i} not strictly sorted: {row}"


def test_spgemm_matches_dense():
    a = _rand_sparse(17, 23, 0.3, 0)
    b = _rand_sparse(23, 11, 0.3, 1)
    C = spgemm(csr_from_dense(a), csr_from_dense(b))
    _assert_csr_invariants(C)
    np.testing.assert_allclose(_dense(C), a @ b, atol=1e-4, rtol=1e-4)


def test_spgemm_rectangular_chain():
    """(m,k)·(k,n) with all three extents distinct — shape plumbing."""
    a = _rand_sparse(5, 31, 0.4, 2)
    b = _rand_sparse(31, 13, 0.4, 3)
    C = spgemm(csr_from_dense(a), csr_from_dense(b))
    assert C.shape == (5, 13)
    np.testing.assert_allclose(_dense(C), a @ b, atol=1e-4, rtol=1e-4)


def test_spgemm_empty_rows():
    """Rows of A with no entries must come out empty, not crash or shift."""
    a = _rand_sparse(9, 9, 0.5, 4)
    a[0] = 0.0
    a[4] = 0.0
    a[8] = 0.0
    b = _rand_sparse(9, 9, 0.5, 5)
    b[:, 2] = 0.0
    C = spgemm(csr_from_dense(a), csr_from_dense(b))
    _assert_csr_invariants(C)
    indptr = np.asarray(C.indptr)
    for i in (0, 4, 8):
        assert indptr[i] == indptr[i + 1]
    np.testing.assert_allclose(_dense(C), a @ b, atol=1e-4, rtol=1e-4)


def test_spgemm_structural_zeros_kept():
    """Products that cancel numerically stay in the pattern — the pattern is
    a pure function of the operand patterns (the serve-cache contract)."""
    # A row [1, -1] against B rows that sum to zero in column 0
    A = csr_from_arrays([0, 2], [0, 1], np.float32([1.0, -1.0]), (1, 2))
    B = csr_from_arrays([0, 1, 2], [0, 0], np.float32([3.0, 3.0]), (2, 1))
    C = spgemm(A, B)
    assert C.nnz == 1  # structurally present...
    np.testing.assert_allclose(np.asarray(C.values), [0.0], atol=1e-6)


def test_spgemm_zero_nnz_and_zero_dim():
    empty = csr_from_arrays([0, 0, 0], [], np.zeros(0, np.float32), (2, 3))
    b = csr_from_dense(_rand_sparse(3, 4, 0.5, 6))
    C = spgemm(empty, b)
    assert C.shape == (2, 4) and C.nnz == 0
    none = csr_from_arrays([0], [], np.zeros(0, np.float32), (0, 3))
    C0 = spgemm(none, b)
    assert C0.shape == (0, 4) and C0.nnz == 0


def test_spgemm_type_and_shape_errors():
    a = csr_from_dense(_rand_sparse(4, 4, 0.5, 7))
    with pytest.raises(TypeError):
        spgemm(a, np.eye(4, dtype=np.float32))
    b = csr_from_dense(_rand_sparse(5, 4, 0.5, 8))
    with pytest.raises(ValueError):
        spgemm(a, b)


def test_sptranspose_matches_dense():
    a = _rand_sparse(13, 7, 0.4, 9)
    T = sptranspose(csr_from_dense(a))
    assert T.shape == (7, 13)
    _assert_csr_invariants(T)
    np.testing.assert_allclose(_dense(T), a.T, atol=1e-6)


def test_sptranspose_involution():
    a = _rand_sparse(11, 17, 0.3, 10)
    A = csr_from_dense(a)
    TT = sptranspose(sptranspose(A))
    np.testing.assert_array_equal(np.asarray(TT.indptr), np.asarray(A.indptr))
    np.testing.assert_array_equal(
        np.asarray(TT.indices), np.asarray(A.indices)
    )
    np.testing.assert_allclose(
        np.asarray(TT.values), np.asarray(A.values), atol=1e-6
    )


def test_sptranspose_empty():
    empty = csr_from_arrays([0, 0], [], np.zeros(0, np.float32), (1, 5))
    T = sptranspose(empty)
    assert T.shape == (5, 1) and T.nnz == 0


@settings(max_examples=8)
@given(
    m=st.integers(1, 24),
    k=st.integers(1, 24),
    n=st.integers(1, 24),
    density=st.floats(0.05, 0.7),
    seed=st.integers(0, 10_000),
)
def test_spgemm_transpose_identity(m, k, n, density, seed):
    """``(Aᵀ·B)ᵀ == Bᵀ·A`` — the algebra the Galerkin product R·A·P leans on
    (R = Pᵀ), checked against the dense oracle on both sides."""
    a = _rand_sparse(k, m, density, seed)
    b = _rand_sparse(k, n, density, seed + 1)
    A = csr_from_dense(a)
    B = csr_from_dense(b)
    lhs = sptranspose(spgemm(sptranspose(A), B))
    rhs = spgemm(sptranspose(B), A)
    np.testing.assert_array_equal(
        np.asarray(lhs.indptr), np.asarray(rhs.indptr)
    )
    np.testing.assert_array_equal(
        np.asarray(lhs.indices), np.asarray(rhs.indices)
    )
    np.testing.assert_allclose(
        np.asarray(lhs.values), np.asarray(rhs.values), atol=1e-3, rtol=1e-3
    )
    np.testing.assert_allclose(_dense(lhs), (a.T @ b).T, atol=1e-3, rtol=1e-3)


@settings(max_examples=6)
@given(
    n=st.integers(1, 20),
    density=st.floats(0.05, 0.8),
    seed=st.integers(0, 10_000),
)
def test_spgemm_structure_identical_across_executors(n, density, seed):
    """The host coalesce pass is shared, so the output structure must be
    bitwise-identical in every kernel space (values to float tolerance)."""
    import repro.kernels  # noqa: F401 — populate the pallas space

    a = _rand_sparse(n, n, density, seed)
    b = _rand_sparse(n, n, density, seed + 1)
    A, B = csr_from_dense(a), csr_from_dense(b)
    ref = spgemm(A, B, executor=make_executor("reference"))
    for kind in ("xla", "pallas_interpret"):
        got = spgemm(A, B, executor=make_executor(kind))
        np.testing.assert_array_equal(
            np.asarray(got.indptr), np.asarray(ref.indptr)
        )
        np.testing.assert_array_equal(
            np.asarray(got.indices), np.asarray(ref.indices)
        )
        np.testing.assert_allclose(
            np.asarray(got.values), np.asarray(ref.values),
            atol=1e-4, rtol=1e-4,
        )
