"""Degenerate-input behavior of the host-side format constructors.

``ell_from_csr_host`` / ``sellp_from_csr_host`` (and the dense wrappers) must
handle empty rows, all-zero matrices, empty matrices, and ``max_nnz=0``
without NaN padding or structures whose apply would launch zero-size kernels
or gather out of bounds (the col-0 padding convention has no column 0 when
the matrix has no columns)."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro import sparse
from repro.core import (
    PallasInterpretExecutor,
    ReferenceExecutor,
    XlaExecutor,
    use_executor,
)

EXECUTORS = [ReferenceExecutor, XlaExecutor, PallasInterpretExecutor]


def _assert_finite(A):
    vals = np.asarray(A.values)
    assert np.isfinite(vals).all(), "constructor emitted non-finite padding"


@pytest.mark.parametrize("builder", ["ell_from_dense", "sellp_from_dense"])
@pytest.mark.parametrize("exec_cls", EXECUTORS)
def test_empty_matrix(builder, exec_cls):
    """0x0 build + apply: no NaNs, no zero-size kernel launch, empty result."""
    A = getattr(sparse, builder)(np.zeros((0, 0), np.float32))
    _assert_finite(A)
    with use_executor(exec_cls()):
        y = sparse.apply(A, jnp.zeros((0,), jnp.float32))
    assert y.shape == (0,)
    assert y.dtype == jnp.float32


def test_sellp_empty_matrix_has_no_phantom_slice():
    A = sparse.sellp_from_dense(np.zeros((0, 0), np.float32))
    assert A.num_slices == 0
    assert A.values.shape == (0,)
    assert A.max_slice_cols == 0


@pytest.mark.parametrize("builder", ["ell_from_dense", "sellp_from_dense"])
@pytest.mark.parametrize("exec_cls", EXECUTORS)
def test_all_zero_matrix(builder, exec_cls, rng):
    """nnz=0 with nonzero shape: finite padding, zero product, f32 dtype."""
    A = getattr(sparse, builder)(np.zeros((6, 9), np.float32))
    _assert_finite(A)
    assert A.dtype == jnp.float32
    x = jnp.asarray(rng.normal(size=(9,)).astype(np.float32))
    with use_executor(exec_cls()):
        y = sparse.apply(A, x)
    np.testing.assert_array_equal(np.asarray(y), np.zeros(6, np.float32))


@pytest.mark.parametrize("exec_cls", EXECUTORS)
def test_ell_explicit_max_nnz_zero(exec_cls):
    """max_nnz=0 must clamp to one padded column, not a (m, 0) value block."""
    A = sparse.ell_from_csr_host(
        np.zeros(6, np.int64), np.zeros(0, np.int64),
        np.zeros(0, np.float32), (5, 5), max_nnz=0,
    )
    assert A.values.shape == (5, 1)
    _assert_finite(A)
    with use_executor(exec_cls()):
        y = sparse.apply(A, jnp.ones(5, jnp.float32))
    np.testing.assert_array_equal(np.asarray(y), np.zeros(5, np.float32))


@pytest.mark.parametrize("builder", ["ell_from_dense", "sellp_from_dense"])
@pytest.mark.parametrize("exec_cls", EXECUTORS)
def test_empty_rows_interleaved(builder, exec_cls, rng):
    """Rows with zero nnz inside an otherwise populated matrix."""
    a = np.zeros((12, 12), np.float32)
    a[3, 5] = 2.0
    a[7, 0] = -1.5
    a[7, 11] = 0.5
    A = getattr(sparse, builder)(a)
    _assert_finite(A)
    x = jnp.asarray(rng.normal(size=(12,)).astype(np.float32))
    with use_executor(exec_cls()):
        y = sparse.apply(A, x)
    np.testing.assert_allclose(np.asarray(y), a @ np.asarray(x), atol=1e-5)


@pytest.mark.parametrize("builder", ["ell_from_dense", "sellp_from_dense"])
def test_empty_to_dense_roundtrip(builder):
    for shape in ((0, 0), (0, 4), (4, 0)):
        A = getattr(sparse, builder)(np.zeros(shape, np.float32))
        d = sparse.to_dense(A, executor=ReferenceExecutor())
        assert d.shape == shape
        assert not np.isnan(np.asarray(d)).any()


def test_zero_column_matrix_apply():
    """(m, 0) @ (0,) -> zeros(m): the col-0 padding has nothing to gather."""
    a = np.zeros((5, 0), np.float32)
    for builder in ("ell_from_dense", "sellp_from_dense", "csr_from_dense",
                    "coo_from_dense"):
        A = getattr(sparse, builder)(a)
        y = sparse.apply(A, jnp.zeros((0,), jnp.float32), executor=XlaExecutor())
        np.testing.assert_array_equal(np.asarray(y), np.zeros(5, np.float32))
