"""BENCH snapshot: the PR's perf surface as one schema'd JSON artifact.

Collects, with the same measurement machinery as the CSV benchmarks:

* achieved GB/s vs :func:`benchmarks.common.spmv_bandwidth_bound` per
  op (plain vs fused SpMV) x format x executor;
* Krylov time-to-tolerance plus fused-vs-unfused-vs-pipelined iteration
  timings on the solve hot path;
* distributed per-shard streaming bandwidth and the psum-per-iteration
  structure of pipelined CG (when the process has multiple devices);
* continuous-batching serve throughput/latency plus the setup cache's
  generation-launch pins (a fully cached request launches zero generates).

The ``pinned`` block holds the values the regression gate
(:mod:`benchmarks.check_regression`) diffs across PR snapshots — chosen to
be structural (launch counts, collective counts, iteration deltas) or
fraction-of-bound ratios, which survive CI timing noise far better than raw
microseconds.

Run:  PYTHONPATH=src python -m benchmarks.run --bench-json BENCH_pr6.json
"""

from __future__ import annotations

import json
import time
from typing import Dict, List

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import (
    banded,
    spmv_bandwidth_bound,
    stencil_2d,
    time_stats,
    tridiag,
)

SCHEMA = "repro-bench/1"
PR = 10


def _spd(n=96):
    a = np.zeros((n, n), np.float32)
    for i in range(n):
        a[i, i] = 4.0
        if i > 0:
            a[i, i - 1] = a[i - 1, i] = -1.0
        if i > 2:
            a[i, i - 3] = a[i - 3, i] = -0.5
    return a


def _spmv_records(bw: float) -> List[dict]:
    """(op x format x executor) achieved GB/s against the roofline bound.

    Besides the returned records, every case publishes live gauges to the
    default metrics registry (``bench_spmv_gbs`` / ``bench_spmv_frac_of_bound``
    per op x format x executor) so a ``--metrics-jsonl`` run exports the same
    roofline surface the pinned block snapshots.
    """
    from repro import sparse
    from repro.core import make_executor, registry
    from repro.observability import metrics

    from repro.sparse.gallery import convection_diffusion_2d

    def _gallery_dense(host_csr):
        indptr, indices, values, shape = host_csr
        a = np.zeros(shape, np.float32)
        rows = np.repeat(np.arange(shape[0]), np.diff(indptr))
        a[rows, indices] = values
        return a

    suite = {
        "stencil2d_16": stencil_2d(16),
        "tridiag_512": tridiag(512),
        "banded_256": banded(256),
        "convdiff_24": _gallery_dense(convection_diffusion_2d(24, peclet=5.0)),
    }
    build = {"csr": sparse.csr_from_dense, "ell": sparse.ell_from_dense}
    # interpret-mode timing is not hardware-representative; one tiny case
    # keeps the executor axis exercised without minutes of interpreter time
    executors = {
        "xla": (make_executor("xla"), set(suite)),
        "pallas_interpret": (make_executor("pallas_interpret"), {"stencil2d_16"}),
    }
    records = []
    for mat_name, a in suite.items():
        n = a.shape[0]
        nnz = int((a != 0).sum())
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
        for fmt, mk in build.items():
            A = mk(a)
            itemsize = np.dtype(A.dtype).itemsize
            bound = spmv_bandwidth_bound(A, bw, nnz)
            for ex_name, (ex, mats) in executors.items():
                if mat_name not in mats:
                    continue
                plain_bytes = A.memory_bytes + 2 * n * itemsize
                fused_bytes = A.memory_bytes + 3 * n * itemsize
                for op_name, fn, bytes_moved in (
                    (
                        f"spmv_{fmt}",
                        jax.jit(lambda xx, A=A, ex=ex: sparse.apply(
                            A, xx, executor=ex)),
                        plain_bytes,
                    ),
                    (
                        f"spmv_dot_{fmt}",
                        jax.jit(lambda xx, A=A, ex=ex: registry.operation(
                            f"spmv_dot_{fmt}")(A, xx, w, executor=ex)),
                        fused_bytes,
                    ),
                ):
                    st = time_stats(fn, x)
                    t = st["time_s"]  # median: what the pins diff
                    gbs = bytes_moved / t / 1e9
                    gflops = 2 * nnz / t / 1e9
                    frac = gbs / (bw / 1e9)
                    labels = dict(op=op_name, format=fmt, executor=ex_name)
                    metrics.gauge("bench_spmv_gbs", **labels).set(gbs)
                    metrics.gauge(
                        "bench_spmv_frac_of_bound", **labels).set(frac)
                    records.append({
                        "kind": "spmv",
                        "op": op_name,
                        "format": fmt,
                        "executor": ex_name,
                        "matrix": mat_name,
                        "time_us": st["time_us"],
                        "min_us": st["min_us"],
                        "warmup": st["warmup"],
                        "repeats": st["repeats"],
                        "gbs": gbs,
                        "bound_gbs": bw / 1e9,
                        "frac_of_bound": frac,
                        "gflops": gflops,
                        "bound_gflops": bound / 1e9,
                    })
    return records


def _solver_records() -> tuple:
    """Fused / unfused / pipelined CG timings + launch accounting."""
    from repro import sparse
    from repro.core import make_executor
    from repro.solvers import Stop
    from repro.solvers.krylov import cg

    a = _spd(256)
    rng = np.random.default_rng(2)
    b = jnp.asarray((a @ rng.normal(size=a.shape[0])).astype(np.float32))
    A = sparse.csr_from_dense(a)
    ex = make_executor("xla")
    stop = Stop(max_iters=500, reduction_factor=1e-6)

    records, pinned = [], {}
    iters = {}
    for variant, opts in (
        ("unfused", {"fused": False}),
        ("fused", {"fused": True}),
        ("pipelined", {"pipeline": True}),
    ):
        fn = jax.jit(lambda bb, opts=opts: cg(
            A, bb, stop=stop, executor=ex, **opts).x)
        st = time_stats(fn, b)
        t = st["time_s"]
        res = cg(A, b, stop=stop, executor=ex, **opts)
        k = int(res.iterations)
        iters[variant] = k
        records.append({
            "kind": "solver",
            "solver": f"cg_{variant}",
            "matrix": "spd_stencil_256",
            "executor": "xla",
            "iterations": k,
            "converged": bool(res.converged),
            "time_to_tol_s": t,
            "min_time_to_tol_s": st["min_s"],
            "warmup": st["warmup"],
            "repeats": st["repeats"],
            "time_per_iter_us": t / max(k, 1) * 1e6,
        })

    # structural launch accounting (trace counts — immune to timing noise)
    ex.dispatch_log.clear()
    cg(A, b, stop=stop, executor=ex, fused=True)
    log = dict(ex.dispatch_log)
    fused_body = log.get("spmv_dot_csr", 0) + log.get("axpy_norm", 0)
    ex.dispatch_log.clear()
    cg(A, b, stop=stop, executor=ex, fused=False)
    log = dict(ex.dispatch_log)
    unfused_body = (
        (log.get("spmv_csr", 0) - 1)
        + (log.get("blas_dot", 0) - 1)
        + (log.get("blas_norm2", 0) - 2)
        + log.get("blas_axpy", 0)
    )
    pinned.update({
        "fused_cg_body_launches": fused_body,
        "unfused_cg_body_launches": unfused_body,
        "fused_unfused_iters_equal": iters["fused"] == iters["unfused"],
        "pipelined_iter_delta": abs(iters["pipelined"] - iters["unfused"]),
        "cg_iterations": iters["unfused"],
    })
    return records, pinned


def _dist_records() -> tuple:
    """Per-shard bandwidth + pipelined psum structure (multi-device only)."""
    from benchmarks.bench_dist import shard_bytes
    from repro import sparse
    from repro.core import make_executor
    from repro.distributed import DistCsr, DistEll, Partition
    from repro.solvers import Stop
    from repro.solvers.krylov import cg

    ndev = len(jax.devices())
    if ndev < 2:
        return [], {}
    a = _spd(96)
    n = a.shape[0]
    nnz = int((a != 0).sum())
    parts = min(ndev, 8)
    part = Partition.uniform(n, parts)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    ex = make_executor("xla")

    records = []
    for fmt, cls in (("csr", DistCsr), ("ell", DistEll)):
        Ad = cls.from_matrix(sparse.csr_from_dense(a), part)
        fn = jax.jit(lambda xx, Ad=Ad: Ad.apply(xx, executor=ex))
        st = time_stats(fn, x)
        t = st["time_s"]
        records.append({
            "kind": "dist_spmv",
            "format": fmt,
            "executor": "xla",
            "parts": parts,
            "matrix": "spd_stencil_96",
            "time_us": st["time_us"],
            "min_us": st["min_us"],
            "warmup": st["warmup"],
            "repeats": st["repeats"],
            "shard_gbs": shard_bytes(Ad, x.dtype.itemsize) / t / 1e9,
            "gflops": 2 * nnz / t / 1e9,
        })

    # psum-per-iteration structure of the sharded solves
    def _find_while(jaxpr):
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "while":
                return eqn
            for v in eqn.params.values():
                sub = getattr(v, "jaxpr", v if hasattr(v, "eqns") else None)
                if sub is not None:
                    w = _find_while(sub)
                    if w is not None:
                        return w
        return None

    def _psums(jaxpr, acc):
        for eqn in jaxpr.eqns:
            if eqn.primitive.name.startswith("psum"):
                acc.append(eqn.primitive.name)
            for v in eqn.params.values():
                sub = getattr(v, "jaxpr", v if hasattr(v, "eqns") else None)
                if sub is not None:
                    _psums(sub, acc)
        return acc

    Ad = DistCsr.from_matrix(sparse.csr_from_dense(a), part)
    b = jnp.asarray((a @ rng.normal(size=n)).astype(np.float32))
    stop = Stop(max_iters=400, reduction_factor=1e-6)
    pinned = {}
    for variant, opts in (("pipelined", {"pipeline": True}), ("standard", {})):
        jaxpr = jax.make_jaxpr(lambda bb, opts=opts: cg(
            Ad, bb, stop=stop, executor=ex, **opts).x)(b)
        w = _find_while(jaxpr.jaxpr)
        pinned[f"psums_per_iteration_{variant}"] = (
            len(_psums(w.params["body_jaxpr"].jaxpr, [])) if w else -1
        )
    return records, pinned


def _serve_records() -> tuple:
    """Continuous-batching solve service: throughput, latency, cache pins.

    The structural pins are dispatch-log generation counts — the setup
    cache's acceptance claim.  Over a repeat-heavy stream the cold pass may
    generate pattern tables only once per distinct pattern, and a request
    whose pattern *and* values are both cached must launch **zero**
    generation operations.  Hit counts are pinned inverted (misses, which
    must not grow) plus the hit rate as a ratio.
    """
    import copy

    from repro.core import make_executor
    from repro.observability import metrics
    from repro.serve import (
        ContinuousBatchEngine,
        ServeConfig,
        TrafficConfig,
        generate_traffic,
    )
    from repro.solvers import Stop

    ex = make_executor("xla")
    config = ServeConfig(slots=4, chunk_sweeps=4,
                         stop=Stop(max_iters=300, reduction_factor=1e-5))
    engine = ContinuousBatchEngine(config, executor=ex)
    traffic = generate_traffic(TrafficConfig(
        num_requests=32, gallery_size=3, repeat_ratio=0.6, n=24, seed=5,
    ))
    # a guaranteed full-hit request: the same matrix as the first arrival
    hit_req = copy.deepcopy(traffic[0][1])

    ex.dispatch_log.clear()
    t0 = time.perf_counter()
    for _, req in traffic:
        engine.submit(req)
    responses = engine.drain()
    wall = time.perf_counter() - t0
    cold_generates = dict(ex.dispatch_log).get("serve_generate_pattern", 0)

    ex.dispatch_log.clear()
    engine.submit(hit_req)
    (hit_resp,) = engine.drain()
    hit_log = dict(ex.dispatch_log)
    hit_generates = (hit_log.get("serve_generate_pattern", 0)
                     + hit_log.get("serve_generate_factors", 0))

    num = len(responses)
    p_hits = sum(r.pattern_hit for r in responses)
    h = metrics.histogram("serve_latency_s")
    records = [{
        "kind": "serve",
        "solver": config.solver,
        "format": config.fmt,
        "executor": "xla",
        "requests": num,
        "slots": config.slots,
        "wall_s": wall,
        "solves_per_s": num / max(wall, 1e-9),
        "iterations": sum(r.iterations for r in responses),
        "latency_p50_s": h.quantile(0.5),
        "latency_p99_s": h.quantile(0.99),
        "pattern_hits": p_hits,
        "factors_hits": sum(r.factors_hit for r in responses),
    }]
    pinned = {
        "serve_cold_generate_launches": int(cold_generates),
        "serve_hit_request_generate_launches": int(hit_generates),
        "serve_pattern_misses": int(num - p_hits),
        "serve_pattern_hit_rate": round(p_hits / num, 4),
        "serve_all_converged": bool(
            all(r.converged for r in responses) and hit_resp.converged
        ),
        "serve_hit_request_full_hit": bool(
            hit_resp.pattern_hit and hit_resp.factors_hit
        ),
    }
    return records, pinned


def _amg_records() -> tuple:
    """AMG-CG vs block-Jacobi-CG on the 10^5-row 2D Poisson problem.

    The PR-9 headline: the smoothed-aggregation hierarchy (built on the
    registered SpGEMM family) must cut CG iterations >=5x and wall
    time-to-tolerance >=2x against the incumbent block-Jacobi lane.  The
    iteration counts and their ratio are deterministic, so they pin as
    numbers; the time ratio is timing-noise-exposed, so it pins as the
    acceptance bool with the measured ratio kept in the records.
    """
    from repro.precond import make_preconditioner
    from repro.solvers import Stop
    from repro.solvers.krylov import cg
    from repro.sparse import csr_from_arrays
    from repro.sparse.gallery import poisson_2d

    n_side = 317  # 100489 rows — the smallest grid past the 1e5 target
    indptr, indices, values, shape = poisson_2d(n_side)
    A = csr_from_arrays(indptr, indices, values.astype(np.float32), shape)
    from repro.core import make_executor

    ex = make_executor("xla")
    stop = Stop(max_iters=2000, reduction_factor=1e-6)
    rng = np.random.default_rng(9)
    b = jnp.asarray(rng.normal(size=shape[0]).astype(np.float32))

    t0 = time.perf_counter()
    M_amg = make_preconditioner(A, "amg", executor=ex)
    setup_s = time.perf_counter() - t0
    M_bj = make_preconditioner(A, "block_jacobi", executor=ex)

    stats, iters, conv = {}, {}, {}
    # the block-Jacobi solve runs ~500+ iterations (~15 s each warm); keep
    # the repeat count low — the ratio, not the absolute time, is the pin
    for name, M in (("block_jacobi", M_bj), ("amg", M_amg)):
        fn = jax.jit(lambda bb, M=M: cg(
            A, bb, stop=stop, M=M, executor=ex).x)
        stats[name] = time_stats(fn, b, warmup=1, repeats=2)
        res = cg(A, b, stop=stop, M=M, executor=ex)
        iters[name] = int(res.iterations)
        conv[name] = bool(res.converged)

    iter_ratio = iters["block_jacobi"] / max(iters["amg"], 1)
    time_ratio = stats["block_jacobi"]["time_s"] / max(
        stats["amg"]["time_s"], 1e-9
    )
    level_rows = [L.A.shape[0] for L in M_amg.levels] + [
        M_amg.coarse_A.shape[0]
    ]
    records = [{
        "kind": "amg",
        "solver": f"cg_{name}",
        "matrix": f"poisson2d_{n_side}",
        "executor": "xla",
        "rows": shape[0],
        "iterations": iters[name],
        "converged": conv[name],
        "time_to_tol_s": stats[name]["time_s"],
        "min_time_to_tol_s": stats[name]["min_s"],
        "warmup": stats[name]["warmup"],
        "repeats": stats[name]["repeats"],
    } for name in ("block_jacobi", "amg")]
    records.append({
        "kind": "amg_hierarchy",
        "matrix": f"poisson2d_{n_side}",
        "num_levels": M_amg.num_levels,
        "level_rows": level_rows,
        "operator_complexity": M_amg.operator_complexity,
        "setup_s": setup_s,
        "iter_ratio": iter_ratio,
        "time_ratio": time_ratio,
    })
    pinned = {
        "amg_cg_iterations": iters["amg"],
        "amg_iter_ratio": round(iter_ratio, 2),
        "amg_time_ratio_ge_2": bool(time_ratio >= 2.0),
        "amg_converged": bool(conv["amg"] and conv["block_jacobi"]),
    }
    return records, pinned


def _nonsym_records() -> tuple:
    """GMRES/BiCGSTAB time-to-tolerance on the nonsymmetric gallery corpus.

    The PR-10 headline: the solver stack handles realistic nonsymmetric
    spectra (convection-diffusion across Péclet regimes) and irregular SPD
    graphs (power-law Laplacians), not just stencil toys.  Iteration counts
    are deterministic and pin as numbers; at 2+ devices the corpus also
    rides the distributed SpMV path at 10^5-row scale.
    """
    from benchmarks.bench_dist import shard_bytes
    from repro.core import make_executor
    from repro.distributed import DistCsr, Partition
    from repro.solvers import Stop
    from repro.solvers.krylov import bicgstab, gmres
    from repro.sparse import csr_from_arrays
    from repro.sparse.gallery import convection_diffusion_2d, power_law_laplacian

    ex = make_executor("xla")
    stop = Stop(max_iters=2000, reduction_factor=1e-6)
    rng = np.random.default_rng(11)

    suite = {
        "convdiff_48_pe0p5": convection_diffusion_2d(48, peclet=0.5,
                                                     scheme="centered"),
        "convdiff_48_pe5": convection_diffusion_2d(48, peclet=5.0,
                                                   scheme="upwind"),
        "powerlaw_2048": power_law_laplacian(2048, seed=4),
    }
    records, pinned = [], {}
    all_converged = True
    for mat_name, (indptr, indices, values, shape) in suite.items():
        A = csr_from_arrays(indptr, indices, values, shape)
        b = jnp.asarray(rng.normal(size=shape[0]).astype(np.float32))
        for solver_name, fn in (("gmres", gmres), ("bicgstab", bicgstab)):
            tfn = jax.jit(lambda bb, fn=fn, A=A: fn(
                A, bb, stop=stop, executor=ex).x)
            st = time_stats(tfn, b, warmup=1, repeats=3)
            res = fn(A, b, stop=stop, executor=ex)
            k = int(res.iterations)
            all_converged = all_converged and bool(res.converged)
            records.append({
                "kind": "nonsym_solver",
                "solver": solver_name,
                "matrix": mat_name,
                "executor": "xla",
                "rows": shape[0],
                "iterations": k,
                "converged": bool(res.converged),
                "time_to_tol_s": st["time_s"],
                "min_time_to_tol_s": st["min_s"],
                "warmup": st["warmup"],
                "repeats": st["repeats"],
            })
            if solver_name == "gmres":
                pinned[f"gmres_{mat_name}_iterations"] = k
    pinned["nonsym_all_converged"] = all_converged

    # distributed SpMV on the nonsymmetric corpus at 10^5-row scale
    ndev = len(jax.devices())
    if ndev >= 2:
        indptr, indices, values, shape = convection_diffusion_2d(
            317, peclet=5.0)  # 100489 rows
        A = csr_from_arrays(indptr, indices, values, shape)
        part = Partition.uniform(shape[0], min(ndev, 8))
        Ad = DistCsr.from_matrix(A, part)
        x = jnp.asarray(rng.normal(size=shape[0]).astype(np.float32))
        ref = np.asarray(A.apply(x))
        got = np.asarray(Ad.apply(x, executor=ex))
        fn = jax.jit(lambda xx, Ad=Ad: Ad.apply(xx, executor=ex))
        st = time_stats(fn, x, warmup=1, repeats=3)
        records.append({
            "kind": "dist_spmv",
            "format": "csr",
            "executor": "xla",
            "parts": int(min(ndev, 8)),
            "matrix": "convdiff_317",
            "rows": shape[0],
            "time_us": st["time_us"],
            "min_us": st["min_us"],
            "warmup": st["warmup"],
            "repeats": st["repeats"],
            "shard_gbs": shard_bytes(Ad, x.dtype.itemsize) / st["time_s"] / 1e9,
        })
        pinned["dist_nonsym_spmv_matches"] = bool(
            np.allclose(got, ref, rtol=1e-4, atol=1e-4)
        )
    return records, pinned


def collect() -> Dict:
    from benchmarks import bench_stream

    print("# stream bandwidth (roofline denominator)")
    bw = bench_stream.run(sizes=(1 << 22,))
    print("# spmv: plain vs fused, per format x executor")
    spmv = _spmv_records(bw)
    print("# solvers: fused / unfused / pipelined CG")
    solver, solver_pinned = _solver_records()
    print("# distributed: per-shard bandwidth + psum structure")
    dist, dist_pinned = _dist_records()
    print("# serve: continuous batching + setup-cache launch pins")
    serve, serve_pinned = _serve_records()
    print("# amg: AMG-CG vs block-Jacobi-CG iteration/time cut")
    amg, amg_pinned = _amg_records()
    print("# nonsym: GMRES/BiCGSTAB on the nonsymmetric gallery corpus")
    nonsym, nonsym_pinned = _nonsym_records()

    pinned = dict(solver_pinned, **dist_pinned, **serve_pinned, **amg_pinned,
                  **nonsym_pinned)
    # frac-of-bound for the pinned spmv cases (xla space: real timings)
    for r in spmv:
        if r["executor"] == "xla":
            pinned[f"frac_{r['op']}_{r['matrix']}"] = round(
                r["frac_of_bound"], 4
            )
    return {
        "schema": SCHEMA,
        "pr": PR,
        "env": {
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "devices": len(jax.devices()),
        },
        "records": spmv + solver + dist + serve + amg + nonsym,
        "pinned": pinned,
    }


def write(path: str) -> str:
    snap = collect()
    with open(path, "w") as f:
        json.dump(snap, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"# wrote {len(snap['records'])} records -> {path}")
    return path
