"""Benchmark utilities: timing, synthetic matrix suite, CSV emission.

The paper benchmarks 100 SuiteSparse matrices; this container is offline, so
the suite below generates seeded synthetic matrices spanning the same regimes
(stencils, banded, random, power-law rows, blocked) — the axis that matters
for format behaviour is the row-length distribution, which these cover.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Tuple

import numpy as np
import jax


def time_stats(fn: Callable, *args, warmup: int = 2, repeats: int = 5) -> dict:
    """Timing statistics for ``fn(*args)``: every repeat blocks on its result.

    Returns a dict with both the median (``time_s`` / ``time_us`` — robust
    to scheduler noise, what the regression gate pins) and the min-of-k
    (``min_s`` / ``min_us`` — the least-noisy estimate of achievable speed,
    what roofline fractions should use), plus the ``warmup``/``repeats``
    protocol so BENCH snapshots are self-describing.
    """
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    med = float(np.median(times))
    best = float(min(times))
    return {
        "time_s": med,
        "min_s": best,
        "time_us": med * 1e6,
        "min_us": best * 1e6,
        "warmup": warmup,
        "repeats": repeats,
    }


def time_fn(fn: Callable, *args, warmup: int = 2, repeats: int = 5) -> float:
    """Median wall seconds per call (blocking on results)."""
    return time_stats(fn, *args, warmup=warmup, repeats=repeats)["time_s"]


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.2f},{derived}")


def spmv_bandwidth_bound(A, bandwidth: float, nnz: int) -> float:
    """Bandwidth-induced FLOP/s bound for one SpMV with format ``A``.

    Uses the format's own ``memory_bytes`` accounting (values + index
    structure, padding included — what the kernel actually streams) plus the
    x gather and y write; 2 flops per *useful* nonzero.  Replaces the old
    per-format bytes/nnz constants, which under-counted padded formats.
    """
    itemsize = np.dtype(A.dtype).itemsize
    m, n = A.shape
    bytes_moved = A.memory_bytes + (n + m) * itemsize
    return bandwidth * 2 * nnz / bytes_moved


# -- synthetic matrix suite -----------------------------------------------------------

def stencil_2d(n_side: int) -> np.ndarray:
    n = n_side * n_side
    a = np.zeros((n, n), np.float32)
    for i in range(n_side):
        for j in range(n_side):
            r = i * n_side + j
            a[r, r] = 4.0
            if i > 0:
                a[r, r - n_side] = -1.0
            if i < n_side - 1:
                a[r, r + n_side] = -1.0
            if j > 0:
                a[r, r - 1] = -1.0
            if j < n_side - 1:
                a[r, r + 1] = -1.0
    return a


def tridiag(n: int) -> np.ndarray:
    a = np.zeros((n, n), np.float32)
    idx = np.arange(n)
    a[idx, idx] = 2.0
    a[idx[1:], idx[:-1]] = -1.0
    a[idx[:-1], idx[1:]] = -1.0
    return a


def banded(n: int, bands=(0, 1, 2, 5, 9), rng=None) -> np.ndarray:
    rng = rng or np.random.default_rng(0)
    a = np.zeros((n, n), np.float32)
    for b in bands:
        v = rng.normal(size=n - b).astype(np.float32)
        a[np.arange(n - b), np.arange(b, n)] = v
        a[np.arange(b, n), np.arange(n - b)] = v
    a[np.arange(n), np.arange(n)] += 10.0
    return a


def random_uniform(n: int, density: float, rng=None) -> np.ndarray:
    rng = rng or np.random.default_rng(1)
    a = rng.normal(size=(n, n)).astype(np.float32)
    a[rng.random((n, n)) >= density] = 0.0
    return a


def power_law_rows(n: int, rng=None) -> np.ndarray:
    """Few very heavy rows, many light ones — the ELL worst case."""
    rng = rng or np.random.default_rng(2)
    a = np.zeros((n, n), np.float32)
    row_nnz = np.minimum((rng.pareto(1.2, size=n) + 1).astype(int) * 2, n // 2)
    for i in range(n):
        cols = rng.choice(n, size=row_nnz[i], replace=False)
        a[i, cols] = rng.normal(size=row_nnz[i])
    return a


def block_diag(n: int, bs: int, rng=None) -> np.ndarray:
    rng = rng or np.random.default_rng(3)
    a = np.zeros((n, n), np.float32)
    for s in range(0, n, bs):
        e = min(s + bs, n)
        a[s:e, s:e] = rng.normal(size=(e - s, e - s))
    return a


def arrow(n: int, rng=None) -> np.ndarray:
    rng = rng or np.random.default_rng(4)
    a = np.zeros((n, n), np.float32)
    a[np.arange(n), np.arange(n)] = 4.0
    a[0, :] = rng.normal(size=n) * 0.1
    a[:, 0] = rng.normal(size=n) * 0.1
    return a


def matrix_suite(small: bool = False) -> Dict[str, np.ndarray]:
    """The SpMV survey suite (paper Figs. 9-11 analogue)."""
    k = 0.5 if small else 1.0
    n1, n2 = int(2048 * k), int(4096 * k)
    return {
        "stencil2d_32": stencil_2d(32),
        "stencil2d_48": stencil_2d(48),
        "tridiag_4k": tridiag(n2),
        "banded_2k": banded(n1),
        "rand0.2%_4k": random_uniform(n2, 0.002),
        "rand1%_2k": random_uniform(n1, 0.01),
        "rand5%_1k": random_uniform(1024, 0.05),
        "powerlaw_2k": power_law_rows(n1),
        "blockdiag_2k": block_diag(n1, 16),
        "arrow_2k": arrow(n1),
    }


def spd_suite(small: bool = False) -> Dict[str, np.ndarray]:
    """Solver suite (paper Figs. 12-14 analogue): 10 SPD systems."""
    mats = {}
    base = matrix_suite(small)
    for name in ("stencil2d_32", "stencil2d_48", "tridiag_4k", "banded_2k"):
        mats[name] = base[name]
    rng = np.random.default_rng(9)
    for i, n in enumerate((512, 768, 1024, 1536, 2048, 3072)):
        a = random_uniform(n, min(0.01 * (i + 1), 0.05), rng).astype(np.float32)
        a = (a + a.T) / 2
        a[np.arange(n), np.arange(n)] = np.abs(a).sum(1) + 1.0  # diag dominant
        mats[f"spd_rand_{n}"] = a
    return mats
