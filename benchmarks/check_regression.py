"""Regression gate over committed BENCH snapshots.

Diffs the current PR's ``BENCH_pr<N>.json`` against the previous PR's
snapshot (highest ``BENCH_pr<M>.json`` with ``M < N`` in the repo root,
when present) and fails on regressions in the ``pinned`` block:

* count-type pins (launches per iteration, psums per iteration, iteration
  deltas, iteration totals) — any INCREASE is a regression (exact compare;
  these are structural, not timing, so noise is not an excuse);
* boolean pins — ``True`` degrading to ``False`` is a regression;
* ratio-valued pins — a drop of more than ``TOLERANCE`` (10%) relative
  to the previous snapshot is a regression; improvements and noise inside
  the band pass;
* fraction-of-bound pins (``frac_*``) — each snapshot's fractions divide
  by its *own* run-measured STREAM bound, so two snapshots taken on
  differently-loaded machines disagree on the denominator even when the
  kernels are byte-identical.  The gate therefore rescales the previous
  pin by the ``bound_gbs`` ratio recorded in both snapshots (equivalent to
  comparing achieved GB/s) and holds it to the wider ``FRAC_TOLERANCE``
  (35%) band: single-kernel microsecond-scale timings swing well past the
  structural 10% band run-to-run, and the pin's job is to catch
  catastrophic bandwidth loss, not to re-litigate timer jitter.

On failure the full per-pin diff table is printed (old vs new vs the
threshold each pin was held to), and the run always ends with one greppable
summary line::

    REGRESSION-GATE: PASS (24 pins vs BENCH_pr6.json)
    REGRESSION-GATE: FAIL (3 regressions in 24 pins vs BENCH_pr6.json)

Exit code 1 on any regression; 0 otherwise (including when no previous
snapshot exists — the first PR that ships a snapshot establishes the
baseline).

Run:  python -m benchmarks.check_regression [--current BENCH_pr6.json]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys

TOLERANCE = 0.10  # >10% drop on ratio-valued pins fails
FRAC_TOLERANCE = 0.35  # wider band for timing-derived frac_* pins


def _stream_bound(snap: dict) -> float | None:
    """The snapshot's measured roofline denominator (GB/s), if recorded."""
    for r in snap.get("records", ()):
        if r.get("kind") == "spmv" and "bound_gbs" in r:
            return float(r["bound_gbs"])
    return None


def _pr_number(path: str) -> int:
    m = re.search(r"BENCH_pr(\d+)\.json$", path)
    return int(m.group(1)) if m else -1


def find_previous(current_path: str) -> str | None:
    cur = _pr_number(current_path)
    root = os.path.dirname(os.path.abspath(current_path)) or "."
    older = [
        p for p in glob.glob(os.path.join(root, "BENCH_pr*.json"))
        if 0 <= _pr_number(p) < cur
    ]
    return max(older, key=_pr_number) if older else None


def compare(prev: dict, cur: dict) -> list:
    """Diff the pinned blocks; one row per pin.

    Each row is ``{"key", "old", "new", "threshold", "status"}`` where
    ``status`` is ``"OK"`` or ``"REGRESSION"`` and ``threshold`` states the
    rule the pin was held to.  Rows for every pin come back (not only the
    failures) so the gate can print a complete diff table.
    """
    rows = []
    prev_pinned = prev.get("pinned", {})
    cur_pinned = cur.get("pinned", {})
    prev_bound, cur_bound = _stream_bound(prev), _stream_bound(cur)
    for key, old in sorted(prev_pinned.items()):
        if key not in cur_pinned:
            rows.append({
                "key": key, "old": old, "new": None,
                "threshold": "must exist", "status": "REGRESSION",
            })
            continue
        new = cur_pinned[key]
        if isinstance(old, bool):
            bad = old and not new
            threshold = "no True -> False"
        elif isinstance(old, int):
            bad = new > old
            threshold = f"<= {old}"
        elif isinstance(old, float):
            if key.startswith("frac_") and prev_bound and cur_bound:
                # normalize away the per-snapshot STREAM denominator:
                # compare achieved GB/s, in the wider timing band
                scaled = old * prev_bound / cur_bound
                floor = scaled * (1.0 - FRAC_TOLERANCE)
                bad = scaled > 0 and new < floor
                threshold = (
                    f">= {floor:.4f} (bound-normalized, "
                    f"-{FRAC_TOLERANCE:.0%})"
                )
            else:
                floor = old * (1.0 - TOLERANCE)
                bad = old > 0 and new < floor
                threshold = f">= {floor:.4f} (-{TOLERANCE:.0%})"
        else:
            bad, threshold = False, "informational"
        rows.append({
            "key": key, "old": old, "new": new,
            "threshold": threshold,
            "status": "REGRESSION" if bad else "OK",
        })
    return rows


def regressions(rows: list) -> list:
    return [r for r in rows if r["status"] == "REGRESSION"]


def render_diff_table(rows: list) -> str:
    """Aligned old-vs-new-vs-threshold table over every pin."""

    def fmt(v):
        if isinstance(v, float):
            return f"{v:.4f}"
        return "missing" if v is None else str(v)

    table = [("pin", "old", "new", "threshold", "status")]
    table += [
        (r["key"], fmt(r["old"]), fmt(r["new"]), r["threshold"], r["status"])
        for r in rows
    ]
    widths = [max(len(row[i]) for row in table) for i in range(5)]
    lines = []
    for j, row in enumerate(table):
        lines.append("  ".join(c.ljust(w) for c, w in zip(row, widths)))
        if j == 0:
            lines.append("  ".join("-" * w for w in widths))
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", default=None,
                    help="current snapshot (default: highest BENCH_pr*.json)")
    ap.add_argument("--previous", default=None,
                    help="previous snapshot (default: auto-discover)")
    args = ap.parse_args(argv)

    current = args.current
    if current is None:
        snaps = sorted(glob.glob("BENCH_pr*.json"), key=_pr_number)
        if not snaps:
            print("no BENCH_pr*.json snapshot found — nothing to gate")
            print("REGRESSION-GATE: PASS (no snapshot)")
            return 0
        current = snaps[-1]
    with open(current) as f:
        cur = json.load(f)
    if cur.get("schema") != "repro-bench/1":
        print(f"{current}: unknown schema {cur.get('schema')!r}")
        print("REGRESSION-GATE: FAIL (bad schema)")
        return 1

    previous = args.previous or find_previous(current)
    if previous is None:
        print(f"{current}: no previous snapshot — baseline established, pass")
        print("REGRESSION-GATE: PASS (baseline)")
        return 0
    with open(previous) as f:
        prev = json.load(f)

    rows = compare(prev, cur)
    bad = regressions(rows)
    prev_name = os.path.basename(previous)
    if bad:
        print(f"REGRESSIONS vs {previous}:")
        print(render_diff_table(rows))
        print(
            f"REGRESSION-GATE: FAIL ({len(bad)} regressions in "
            f"{len(rows)} pins vs {prev_name})"
        )
        return 1
    print(f"{current}: {len(rows)} pinned cases OK vs {previous}")
    print(f"REGRESSION-GATE: PASS ({len(rows)} pins vs {prev_name})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
