"""Regression gate over committed BENCH snapshots.

Diffs the current PR's ``BENCH_pr<N>.json`` against the previous PR's
snapshot (highest ``BENCH_pr<M>.json`` with ``M < N`` in the repo root,
when present) and fails on regressions in the ``pinned`` block:

* count-type pins (launches per iteration, psums per iteration, iteration
  deltas, iteration totals) — any INCREASE is a regression (exact compare;
  these are structural, not timing, so noise is not an excuse);
* boolean pins — ``True`` degrading to ``False`` is a regression;
* fraction-of-bound pins — a drop of more than ``TOLERANCE`` (10%) relative
  to the previous snapshot is a regression; improvements and noise inside
  the band pass.

Exit code 1 on any regression; 0 otherwise (including when no previous
snapshot exists — the first PR that ships a snapshot establishes the
baseline).

Run:  python -m benchmarks.check_regression [--current BENCH_pr6.json]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys

TOLERANCE = 0.10  # >10% drop on ratio-valued pins fails


def _pr_number(path: str) -> int:
    m = re.search(r"BENCH_pr(\d+)\.json$", path)
    return int(m.group(1)) if m else -1


def find_previous(current_path: str) -> str | None:
    cur = _pr_number(current_path)
    root = os.path.dirname(os.path.abspath(current_path)) or "."
    older = [
        p for p in glob.glob(os.path.join(root, "BENCH_pr*.json"))
        if 0 <= _pr_number(p) < cur
    ]
    return max(older, key=_pr_number) if older else None


def compare(prev: dict, cur: dict) -> list:
    """Return a list of human-readable regression descriptions."""
    regressions = []
    prev_pinned = prev.get("pinned", {})
    cur_pinned = cur.get("pinned", {})
    for key, old in sorted(prev_pinned.items()):
        if key not in cur_pinned:
            regressions.append(f"pinned case {key!r} disappeared")
            continue
        new = cur_pinned[key]
        if isinstance(old, bool):
            if old and not new:
                regressions.append(f"{key}: True -> False")
        elif isinstance(old, int):
            if new > old:
                regressions.append(f"{key}: {old} -> {new} (count increased)")
        elif isinstance(old, float):
            if old > 0 and new < old * (1.0 - TOLERANCE):
                regressions.append(
                    f"{key}: {old:.4f} -> {new:.4f} "
                    f"(dropped more than {TOLERANCE:.0%})"
                )
    return regressions


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", default=None,
                    help="current snapshot (default: highest BENCH_pr*.json)")
    ap.add_argument("--previous", default=None,
                    help="previous snapshot (default: auto-discover)")
    args = ap.parse_args(argv)

    current = args.current
    if current is None:
        snaps = sorted(glob.glob("BENCH_pr*.json"), key=_pr_number)
        if not snaps:
            print("no BENCH_pr*.json snapshot found — nothing to gate")
            return 0
        current = snaps[-1]
    with open(current) as f:
        cur = json.load(f)
    if cur.get("schema") != "repro-bench/1":
        print(f"{current}: unknown schema {cur.get('schema')!r}")
        return 1

    previous = args.previous or find_previous(current)
    if previous is None:
        print(f"{current}: no previous snapshot — baseline established, pass")
        return 0
    with open(previous) as f:
        prev = json.load(f)

    regressions = compare(prev, cur)
    if regressions:
        print(f"REGRESSIONS vs {previous}:")
        for r in regressions:
            print(f"  - {r}")
        return 1
    print(
        f"{current}: {len(cur.get('pinned', {}))} pinned cases OK "
        f"vs {previous}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
