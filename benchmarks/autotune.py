"""Autotune sweep: measure candidate tile geometries per op, persist winners.

The launch-configuration resolver (``repro.core.tuning``) falls back to
HardwareParams-derived seeds; this sweep replaces guesses with measurements.
For every op that has a tuning spec it times each candidate geometry on a
representative shape, records the winner in the shape-bucketed autotune cache,
and persists the cache as a per-target table (JSON) that
``tuning.load_table`` / ``REPRO_TUNING_PATH`` can reload.

Run:  PYTHONPATH=src python -m benchmarks.run --autotune
      PYTHONPATH=src python -m benchmarks.autotune --target cpu_interpret \
          --out benchmarks/tuning/cpu_interpret.json

On CPU the pallas kernels run in interpret mode — the absolute times are not
hardware-representative, but the sweep is the same end-to-end machinery a TPU
run uses (candidate generation -> constrain -> VMEM filter -> measure ->
persist), which is what the portability story needs exercised.
"""

from __future__ import annotations

import argparse
import os
from typing import Callable, Dict, Optional

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import make_executor, tuning


def _np_rng():
    return np.random.default_rng(0)


# -- per-op runners -----------------------------------------------------------
# Each builder returns (shapes, run) where run(block) executes the kernel once
# with that explicit geometry (blocking).  Shapes are kept small enough for
# CPU interpret mode; on real hardware pass --full-ish shapes via the table.


def _attention_runner(ex):
    from repro.kernels.flash_attention.kernel import flash_attention

    rng = _np_rng()
    B, H, S, D = 1, 2, 256, 64
    q = jnp.asarray(rng.normal(size=(B, H, S, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, H, S, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, H, S, D)).astype(np.float32))
    shapes = {"S": S, "Skv": S, "D": D, "itemsize": 4}

    def run(block):
        return time_fn(
            lambda: flash_attention(
                q, k, v,
                block_q=block["block_q"], block_kv=block["block_kv"],
                interpret=ex.interpret,
            ),
            warmup=1, repeats=3,
        )

    return shapes, run


def _chunked_attention_runner(ex):
    from repro.nn.attention import attention_xla_chunked

    rng = _np_rng()
    B, H, S, D = 1, 2, 512, 64
    q = jnp.asarray(rng.normal(size=(B, H, S, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, H, S, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, H, S, D)).astype(np.float32))
    shapes = {"S": S, "Skv": S, "D": D, "itemsize": 4}

    def run(block):
        return time_fn(
            lambda: attention_xla_chunked(q, k, v, chunk=block["chunk"]),
            warmup=1, repeats=3,
        )

    return shapes, run


def _rmsnorm_runner(ex):
    from repro.kernels.rmsnorm.kernel import rmsnorm

    rng = _np_rng()
    rows, d = 2048, 512
    x = jnp.asarray(rng.normal(size=(rows, d)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
    shapes = {"rows": rows, "d": d, "itemsize": 4}

    def run(block):
        return time_fn(
            lambda: rmsnorm(
                x, w, block_rows=block["block_rows"], interpret=ex.interpret
            ),
            warmup=1, repeats=3,
        )

    return shapes, run


def _rwkv6_runner(ex):
    from repro.kernels.rwkv6.kernel import rwkv6_scan_log
    from repro.kernels.rwkv6.xla import rwkv6_chunked_xla

    rng = _np_rng()
    B, S, H, K = 1, 128, 2, 32
    r = jnp.asarray(rng.normal(size=(B, S, H, K)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, H, K)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, H, K)).astype(np.float32))
    logw = jnp.asarray(-np.exp(rng.normal(-1.0, 1.0, size=(B, S, H, K))).astype(np.float32))
    u = jnp.asarray(rng.normal(size=(H, K)).astype(np.float32))
    shapes = {"S": S, "K": K, "V": K, "itemsize": 4}
    pallas = ex.kernel_space == "pallas"

    def run(block):
        if pallas:
            fn = lambda: rwkv6_scan_log(
                r, k, v, logw, u, chunk=block["chunk"], interpret=ex.interpret
            )
        else:
            fn = lambda: rwkv6_chunked_xla(r, k, v, logw, u, chunk=block["chunk"])
        return time_fn(fn, warmup=1, repeats=3)

    return shapes, run


def _ssd_runner(ex):
    from repro.kernels.ssd.kernel import ssd_scan
    from repro.kernels.ssd.xla import ssd_chunked_xla

    rng = _np_rng()
    B, S, H, P, G, N = 1, 128, 2, 32, 1, 16
    x = jnp.asarray(rng.normal(size=(B, S, H, P)).astype(np.float32))
    dt = jnp.asarray(np.log1p(np.exp(rng.normal(size=(B, S, H)))).astype(np.float32))
    A = jnp.asarray(-np.exp(rng.normal(size=(H,))).astype(np.float32))
    Bm = jnp.asarray(rng.normal(size=(B, S, G, N)).astype(np.float32))
    C = jnp.asarray(rng.normal(size=(B, S, G, N)).astype(np.float32))
    shapes = {"S": S, "N": N, "P": P, "itemsize": 4}
    pallas = ex.kernel_space == "pallas"

    def run(block):
        if pallas:
            fn = lambda: ssd_scan(
                x, dt, A, Bm, C, chunk=block["chunk"], interpret=ex.interpret
            )
        else:
            fn = lambda: ssd_chunked_xla(x, dt, A, Bm, C, chunk=block["chunk"])
        return time_fn(fn, warmup=1, repeats=3)

    return shapes, run


def _spmv_ell_runner(ex):
    from repro.kernels.spmv_ell.kernel import spmv_ell
    from repro.sparse.formats import ell_from_csr_host
    from repro.sparse.gallery import power_law_laplacian

    rng = _np_rng()
    # irregular-degree gallery graph: realistic ELL padding, unlike a
    # uniform-density random matrix
    indptr, indices, values, shape = power_law_laplacian(512, seed=0)
    A = ell_from_csr_host(indptr, indices, values, shape)
    n = shape[0]
    x = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    shapes = {
        "m": A.values.shape[0], "k": A.values.shape[1], "n": n, "itemsize": 4
    }

    def run(block):
        return time_fn(
            lambda: spmv_ell(
                A.col_idx, A.values, x,
                block_m=block["block_m"], block_k=block["block_k"],
                interpret=ex.interpret,
            ),
            warmup=1, repeats=3,
        )

    return shapes, run


def _spmv_dot_runner(ex):
    from repro.kernels.spmv_dot.kernel import spmv_dot_ell
    from repro.sparse.formats import ell_from_csr_host
    from repro.sparse.gallery import power_law_laplacian

    rng = _np_rng()
    indptr, indices, values, shape = power_law_laplacian(512, seed=0)
    A = ell_from_csr_host(indptr, indices, values, shape)
    n = shape[0]
    x = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    shapes = {
        "m": A.values.shape[0], "k": A.values.shape[1], "n": n, "itemsize": 4
    }

    def run(block):
        return time_fn(
            lambda: spmv_dot_ell(
                A.col_idx, A.values, x, w,
                block_m=block["block_m"], block_k=block["block_k"],
                interpret=ex.interpret,
            ),
            warmup=1, repeats=3,
        )

    return shapes, run


def _axpy_norm_runner(ex):
    from repro.kernels.axpy_norm.kernel import axpy_norm

    rng = _np_rng()
    n = 1 << 16
    x = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    y = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    shapes = {"n": n, "itemsize": 4}

    def run(block):
        return time_fn(
            lambda: axpy_norm(
                0.5, x, y, block_n=block["block_n"], interpret=ex.interpret
            ),
            warmup=1, repeats=3,
        )

    return shapes, run


def _spmv_sellp_runner(ex):
    from repro.kernels.spmv_sellp.kernel import spmv_sellp
    from repro.sparse.formats import sellp_from_csr_host
    from repro.sparse.gallery import convection_diffusion_2d

    rng = _np_rng()
    # nonsymmetric gallery stencil at the same 512-row scale the sweep used
    indptr, indices, values, shape = convection_diffusion_2d(23, peclet=5.0)
    A = sellp_from_csr_host(indptr, indices, values, shape)
    n = shape[0]
    x = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    shapes = {
        "m": n, "n": n, "slice_size": A.slice_size,
        "stride_factor": A.stride_factor, "itemsize": 4,
    }

    def run(block):
        return time_fn(
            lambda: spmv_sellp(
                A.col_idx, A.values, A.slice_sets, x,
                m=n, slice_size=A.slice_size, block_cols=block["block_cols"],
                max_slice_cols=A.max_slice_cols, interpret=ex.interpret,
            ),
            warmup=1, repeats=3,
        )

    return shapes, run


def _spgemm_runner(ex):
    from repro.kernels.spgemm.kernel import spgemm_expand
    from repro.sparse.formats import csr_from_arrays
    from repro.sparse.gallery import poisson_2d
    from repro.sparse.ops import _spgemm_maps

    indptr, indices, values, shape = poisson_2d(32)
    A = csr_from_arrays(indptr, indices, values.astype(np.float32), shape)
    # representative A·A workload: host structure pass once, then time only
    # the numeric expansion the candidate geometry actually tiles
    rows_a, b_start, b_len, K = _spgemm_maps(A, A)
    q = np.arange(K)
    valid = q[None, :] < b_len[:, None]
    idx1 = jnp.asarray(
        np.where(valid, b_start[:, None] + q[None, :] + 1, 0).astype(np.int32)
    )
    b_pad = jnp.concatenate([jnp.zeros(1, A.values.dtype), A.values])
    shapes = {"t": rows_a.size, "k": K, "nnzb": A.nnz, "itemsize": 4}

    def run(block):
        return time_fn(
            lambda: spgemm_expand(
                A.values, idx1, b_pad,
                block_t=block["block_t"], block_k=block["block_k"],
                interpret=ex.interpret,
            ),
            warmup=1, repeats=3,
        )

    return shapes, run


def _block_jacobi_runner(ex):
    from repro.kernels.block_jacobi.kernel import block_jacobi_apply

    rng = _np_rng()
    nb, bs = 512, 8
    inv = jnp.asarray(rng.normal(size=(nb, bs, bs)).astype(np.float32))
    vp = jnp.asarray(rng.normal(size=(nb, bs)).astype(np.float32))
    shapes = {"nb": nb, "bs": bs, "itemsize": 4}

    def run(block):
        return time_fn(
            lambda: block_jacobi_apply(
                inv, vp, block_nb=block["block_nb"], interpret=ex.interpret
            ),
            warmup=1, repeats=3,
        )

    return shapes, run


def _spmv_batch_ell_runner(ex):
    from repro import batch as batch_lib
    from repro.kernels.spmv_batch_ell.kernel import spmv_batch_ell

    rng = _np_rng()
    nb, n = 32, 256
    # one sparsity pattern shared across the batch (the fast path and the
    # representative batched workload); independent patterns would union
    # into a uselessly wide ELL block
    pattern = rng.random((n, n)) < 0.05
    stack = np.where(
        pattern[None], rng.normal(size=(nb, n, n)).astype(np.float32), 0.0
    )
    A = batch_lib.batch_ell_from_dense(stack)
    X = jnp.asarray(rng.normal(size=(nb, n)).astype(np.float32))
    shapes = {
        "nb": nb, "m": A.values.shape[1], "k": A.values.shape[2],
        "n": n, "itemsize": 4,
    }

    def run(block):
        return time_fn(
            lambda: spmv_batch_ell(
                A.col_idx, A.values, X,
                block_m=block["block_m"], block_k=block["block_k"],
                interpret=ex.interpret,
            ),
            warmup=1, repeats=3,
        )

    return shapes, run


#: op -> (runner builder, kernel spaces the sweep applies to)
RUNNERS: Dict[str, tuple] = {
    "nn_attention": (_attention_runner, ("pallas",)),
    "nn_attention_chunked": (_chunked_attention_runner, ("xla", "reference")),
    "nn_rmsnorm": (_rmsnorm_runner, ("pallas",)),
    "nn_rwkv6_scan": (_rwkv6_runner, ("pallas", "xla")),
    "nn_ssd_scan": (_ssd_runner, ("pallas", "xla")),
    "spmv_ell": (_spmv_ell_runner, ("pallas",)),
    "spmv_dot": (_spmv_dot_runner, ("pallas",)),
    "axpy_norm": (_axpy_norm_runner, ("pallas",)),
    "spmv_sellp": (_spmv_sellp_runner, ("pallas",)),
    "spmv_batch_ell": (_spmv_batch_ell_runner, ("pallas",)),
    "spgemm": (_spgemm_runner, ("pallas",)),
    "block_jacobi": (_block_jacobi_runner, ("pallas",)),
}


def run(
    target: str = "cpu_interpret",
    out: Optional[str] = None,
    ops: Optional[list] = None,
) -> str:
    """Sweep all applicable ops for ``target``; persist and return the table path."""
    ex = make_executor(target)
    hw = ex.hw
    budget = hw.vmem_limit_bytes // tuning.VMEM_HEADROOM
    if out is None:
        out = os.path.join(os.path.dirname(__file__), "tuning", f"{hw.name}.json")
    # preload the existing table so a subset sweep (--ops) refreshes only its
    # ops and re-persists the rest unchanged
    if os.path.exists(out):
        tuning.load_table(out)
    for op, (builder, spaces) in RUNNERS.items():
        if ops and op not in ops:
            continue
        if ex.kernel_space not in spaces:
            print(f"# skipped {op}: applies to {spaces}, target "
                  f"{target!r} runs the {ex.kernel_space!r} space "
                  f"(sweep it with a matching --target)")
            continue
        spec = tuning.get_spec(op)
        if spec.candidates is None:
            continue
        shapes, bench = builder(ex)
        seen, best = set(), None
        for cand in spec.candidates(hw, shapes):
            if spec.constrain is not None:
                cand = spec.constrain(hw, shapes, cand)
            key = tuple(sorted(cand.items()))
            if key in seen:
                continue
            seen.add(key)
            if spec.vmem_bytes(shapes, cand) > budget:
                continue
            secs = bench(cand)
            emit(f"autotune.{op}.{_slug(cand)}", secs * 1e6, f"target={target}")
            if best is None or secs < best[0]:
                best = (secs, cand)
        if best is not None:
            tuning.record_autotuned(op, hw.name, shapes, best[1])
            emit(f"autotune.{op}.winner.{_slug(best[1])}", best[0] * 1e6,
                 f"target={target}")
    # save everything in the cache (the preloaded file + this sweep's
    # winners): filtering to hw.name here would drop other targets' entries
    # when --out points at a shared multi-target table
    n = tuning.save_table(out)
    print(f"# persisted {n} tuned entries -> {out}")
    return out


def _slug(block: Dict[str, int]) -> str:
    return "_".join(f"{k.split('_')[-1]}{v}" for k, v in sorted(block.items()))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--target", default="cpu_interpret",
                    help="hardware target name (see repro.core.params.TARGETS)")
    ap.add_argument("--out", default=None, help="output JSON path")
    ap.add_argument("--ops", nargs="*", default=None, help="subset of ops")
    args = ap.parse_args()
    run(target=args.target, out=args.out, ops=args.ops)


if __name__ == "__main__":
    main()
