"""SpMV survey (paper Figs. 9-11): formats x matrix suite x executors.

Reports GFLOP/s (2*nnz / t) and the fraction of the bandwidth-induced bound —
the paper's performance-portability metric.  Bound per format (f32):

    bytes/nnz: value 4 + column index 4 (+ row structure, amortized)
    CSR/ELL ~ 8 B per 2 flops -> bound = BW/4
    COO     ~ 12 B per 2 flops -> bound = BW/6
    SELL-P  ~ 8 B per 2 flops on stored (padded) entries

(The paper's f64 constants are BW/6 and BW/8; f32 halves the value bytes.)
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, matrix_suite, time_fn
from repro import sparse
from repro.core import PallasInterpretExecutor, XlaExecutor, use_executor

BOUND_DIVISOR = {"coo": 6.0, "csr": 4.0, "ell": 4.0, "sellp": 4.0}


def run(bandwidth: float, small: bool = False, pallas: bool = False) -> None:
    suite = matrix_suite(small)
    rng = np.random.default_rng(7)
    execs = [("xla", XlaExecutor())]
    if pallas:
        # interpret-mode timing is NOT indicative of TPU perf; included only
        # to exercise the path (off by default)
        execs.append(("pallas_interp", PallasInterpretExecutor()))

    for mat_name, a in suite.items():
        nnz = int((a != 0).sum())
        x = jnp.asarray(rng.normal(size=(a.shape[1],)).astype(np.float32))
        mats = {
            "coo": sparse.coo_from_dense(a),
            "csr": sparse.csr_from_dense(a),
            "ell": sparse.ell_from_dense(a),
            "sellp": sparse.sellp_from_dense(a),
        }
        for ex_name, ex in execs:
            with use_executor(ex):
                for fmt, A in mats.items():
                    fn = jax.jit(lambda x, A=A: sparse.apply(A, x))
                    t = time_fn(fn, x)
                    gflops = 2 * nnz / t / 1e9
                    bound = bandwidth / BOUND_DIVISOR[fmt] / 1e9
                    emit(
                        f"spmv_{ex_name}_{fmt}_{mat_name}",
                        t * 1e6,
                        f"{gflops:.3f}GFLOP/s_frac{gflops/bound:.2f}",
                    )


if __name__ == "__main__":
    from benchmarks.bench_stream import run as stream_run

    bw = stream_run(sizes=(1 << 22,))
    run(bw, small=True)
