"""SpMV survey (paper Figs. 9-11): formats x matrix suite x executors.

Reports GFLOP/s (2*nnz / t) and the fraction of the bandwidth-induced bound —
the paper's performance-portability metric.  The bound comes from each
format's own ``memory_bytes`` accounting (``spmv_bandwidth_bound`` in
benchmarks/common.py): stored values + index structure + the x/y vectors,
2 flops per useful nonzero — so padded formats (ELL, SELL-P) are charged for
the padding their kernels actually stream.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, matrix_suite, spmv_bandwidth_bound, time_fn
from repro import sparse
from repro.core import PallasInterpretExecutor, XlaExecutor, use_executor


def run(bandwidth: float, small: bool = False, pallas: bool = False) -> None:
    suite = matrix_suite(small)
    rng = np.random.default_rng(7)
    execs = [("xla", XlaExecutor())]
    if pallas:
        # interpret-mode timing is NOT indicative of TPU perf; included only
        # to exercise the path (off by default)
        execs.append(("pallas_interp", PallasInterpretExecutor()))

    for mat_name, a in suite.items():
        nnz = int((a != 0).sum())
        x = jnp.asarray(rng.normal(size=(a.shape[1],)).astype(np.float32))
        mats = {
            "coo": sparse.coo_from_dense(a),
            "csr": sparse.csr_from_dense(a),
            "ell": sparse.ell_from_dense(a),
            "sellp": sparse.sellp_from_dense(a),
        }
        for ex_name, ex in execs:
            with use_executor(ex):
                for fmt, A in mats.items():
                    fn = jax.jit(lambda x, A=A: sparse.apply(A, x))
                    t = time_fn(fn, x)
                    gflops = 2 * nnz / t / 1e9
                    bound = spmv_bandwidth_bound(A, bandwidth, nnz) / 1e9
                    emit(
                        f"spmv_{ex_name}_{fmt}_{mat_name}",
                        t * 1e6,
                        f"{gflops:.3f}GFLOP/s_frac{gflops/bound:.2f}",
                    )


if __name__ == "__main__":
    from benchmarks.bench_stream import run as stream_run

    bw = stream_run(sizes=(1 << 22,))
    run(bw, small=True)
