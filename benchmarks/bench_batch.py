"""Batched linear algebra benchmark: one launch vs a loop of single solves.

The paper's batched pitch is launch-count economics: N small systems in one
kernel launch instead of N launches.  This benchmark measures both sides —
``spmv_batch_ell`` against a loop of single-system ELL SpMVs, and the masked
batched CG against a loop of single-system CG solves — and emits the usual
``name,us_per_call,derived`` CSV lines with the batched-over-loop speedup.

``run(smoke=True)`` is the CI smoke: one small batched solve end to end,
asserting convergence so kernel-launch regressions fail the step rather than
silently emitting garbage timings.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro import batch as batch_lib
from repro import solvers, sparse
from repro.core import XlaExecutor, use_executor
from repro.launch.batch_solve import build_batch


def _bench_spmv(nb: int, n: int) -> None:
    rng = np.random.default_rng(11)
    # one sparsity pattern shared across the batch — the representative
    # batched workload; independent patterns would union into a near-dense
    # ELL block and this would measure dense matvec economics instead
    pattern = rng.random((n, n)) < 0.05
    stack = np.where(
        pattern[None], rng.normal(size=(nb, n, n)).astype(np.float32), 0.0
    )
    A = batch_lib.batch_ell_from_dense(stack)
    X = jnp.asarray(rng.normal(size=(nb, n)).astype(np.float32))
    singles = [A.system(b) for b in range(nb)]

    with use_executor(XlaExecutor()):
        batched = jax.jit(lambda X: batch_lib.apply_batch(A, X))
        t_batch = time_fn(batched, X)

        single = jax.jit(lambda A, x: sparse.apply(A, x))
        def loop(X):
            return [single(singles[b], X[b]) for b in range(nb)]
        t_loop = time_fn(loop, X)

    emit(f"batch_spmv_ell_nb{nb}_n{n}", t_batch * 1e6,
         f"loop{t_loop*1e6:.1f}us_speedup{t_loop/t_batch:.1f}x")


def _bench_solve(nb: int, n: int, *, smoke: bool = False) -> None:
    A, B, xstar = build_batch(nb, n, fmt="ell")
    stop = solvers.Stop(max_iters=200, reduction_factor=1e-6)

    with use_executor(XlaExecutor()):
        batched = jax.jit(lambda B: batch_lib.batch_cg(A, B, stop=stop))
        res = batched(B)
        conv = np.asarray(res.converged)
        assert conv.all(), (
            f"batched CG smoke failed: {int(conv.sum())}/{conv.size} converged"
        )
        err = np.abs(np.asarray(res.x) - xstar).max()
        assert err < 1e-3, f"batched CG smoke solution error {err}"
        t_batch = time_fn(batched, B, warmup=1, repeats=3)

        if smoke:
            iters = np.asarray(res.iterations)
            emit(f"batch_cg_ell_nb{nb}_n{n}", t_batch * 1e6,
                 f"iters{iters.min()}-{iters.max()}_allconverged")
            return

        single = jax.jit(
            lambda A, b: solvers.cg(A, b, stop=stop),
            static_argnums=(),
        )
        singles = [A.system(b) for b in range(nb)]
        def loop(B):
            return [single(singles[b], B[b]).x for b in range(nb)]
        t_loop = time_fn(loop, B, warmup=1, repeats=3)

    emit(f"batch_cg_ell_nb{nb}_n{n}", t_batch * 1e6,
         f"loop{t_loop*1e6:.1f}us_speedup{t_loop/t_batch:.1f}x")


def run(small: bool = False, smoke: bool = False) -> None:
    if smoke:
        _bench_solve(32, 32, smoke=True)
        return
    nb, n = (64, 48) if small else (256, 64)
    _bench_spmv(nb, n)
    _bench_solve(nb, n)


if __name__ == "__main__":
    run(small=True)
