"""Distributed SpMV benchmark: per-shard achieved bandwidth vs the bound.

For each suite matrix, row-partition over the available devices and time the
halo-exchange SpMV (local block + gathered-column remote block under
``shard_map``).  Reported per matrix:

* achieved GFLOP/s (2 * true nnz / t) and the fraction of the single-device
  bandwidth-induced bound (``spmv_bandwidth_bound`` over the underlying
  format's own byte accounting) — the paper's performance-portability metric,
  now per shard;
* per-shard achieved bandwidth GB/s: the bytes one shard actually streams
  (its slice of the distributed operator + the gathered x + its y chunk)
  over the wall time, next to the machine bandwidth the bound assumes.

Interpret-mode CPU timings are not TPU-indicative; the point in CI (--smoke)
is that the sharded path runs end to end and the accounting adds up.
"""

from __future__ import annotations

import argparse

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import (
    banded,
    emit,
    matrix_suite,
    spmv_bandwidth_bound,
    stencil_2d,
    time_fn,
    tridiag,
)
from repro import sparse
from repro.core import XlaExecutor, use_executor
from repro.distributed import DistCsr, DistEll, Partition
from repro.solvers import krylov
from repro.solvers.common import Stop

DIST_BUILD = {
    "csr": (sparse.csr_from_dense, DistCsr),
    "ell": (sparse.ell_from_dense, DistEll),
}


def shard_bytes(Ad, x_itemsize: int) -> float:
    """Bytes ONE shard streams per apply: its slice of the operator, the
    all-gathered x (padded global), and its padded y chunk."""
    P = Ad.partition.num_parts
    Lmax = Ad.partition.max_part_size
    return Ad.memory_bytes / P + (P * Lmax + Lmax) * x_itemsize


def run(bandwidth: float, smoke: bool = False) -> None:
    ndev = len(jax.devices())
    suite = (
        # compact smoke suite: one matrix per structural regime, CI-sized
        {
            "stencil2d_16": stencil_2d(16),
            "tridiag_512": tridiag(512),
            "banded_256": banded(256),
        }
        if smoke
        else matrix_suite()
    )
    rng = np.random.default_rng(7)
    ex = XlaExecutor()

    with use_executor(ex):
        for mat_name, a in suite.items():
            n = a.shape[0]
            nnz = int((a != 0).sum())
            parts = min(ndev, n)
            part = Partition.uniform(n, parts)
            x = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
            for fmt, (build, dist_cls) in DIST_BUILD.items():
                A = build(a)
                Ad = dist_cls.from_matrix(A, part)
                fn = jax.jit(lambda x, Ad=Ad: Ad.apply(x, executor=ex))
                t = time_fn(fn, x)
                gflops = 2 * nnz / t / 1e9
                bound = spmv_bandwidth_bound(A, bandwidth, nnz) / 1e9
                shard_gbs = shard_bytes(Ad, x.dtype.itemsize) / t / 1e9
                emit(
                    f"dist_spmv_{fmt}_{mat_name}_{parts}shard",
                    t * 1e6,
                    f"{gflops:.3f}GFLOP/s_frac{gflops/bound:.2f}"
                    f"_shardbw{shard_gbs:.3g}GB/s_of{bandwidth/1e9:.0f}GB/s",
                )

        if smoke:
            # end-to-end sharded CG must actually converge in CI
            n = 225
            from repro.launch.dist_solve import build_system

            a, xstar, b = build_system(n)
            Ad = DistCsr.from_matrix(
                sparse.csr_from_dense(a), Partition.uniform(n, min(ndev, 8))
            )
            res = krylov.cg(
                Ad, jnp.asarray(b), stop=Stop(max_iters=500), executor=ex
            )
            assert bool(res.converged), "distributed CG smoke did not converge"
            err = float(np.abs(np.asarray(res.x) - xstar).max())
            assert err < 1e-3, f"distributed CG smoke error {err}"
            print(f"# dist cg smoke: {int(res.iterations)} iters, err {err:.2e}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="small suite + CG check")
    ap.add_argument(
        "--bandwidth", type=float, default=None,
        help="machine bandwidth B/s for the bound (default: hw table)",
    )
    args = ap.parse_args(argv)
    bw = args.bandwidth or XlaExecutor().hw.hbm_bandwidth
    print(f"# distributed spmv over {len(jax.devices())} device(s), "
          f"bound bandwidth {bw/1e9:.0f} GB/s")
    run(bw, smoke=args.smoke)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
