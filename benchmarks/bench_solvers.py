"""Krylov solver survey (paper Figs. 12-14): GFLOP/s vs the ai=1 bound.

The paper runs each solver 10k iterations on 10 matrices and reports
GFLOP/s against the aggressive arithmetic-intensity-1 bound (BW / bytes-per-
value: f64 -> BW/8; here f32 -> BW/4).  We run a fixed iteration budget
(restart-free stopping disabled) and count flops structurally:

    per CG iteration: 1 SpMV (2 nnz) + 3 axpy (2n) + 2 dots (2n) + norm (2n)
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, spd_suite, time_fn
from repro import solvers, sparse
from repro.core import XlaExecutor, use_executor

ITERS = 200


def flops_per_iter(kind: str, nnz: int, n: int) -> float:
    spmv = 2 * nnz
    axpy = 2 * n
    dot = 2 * n
    if kind == "cg":
        return spmv + 3 * axpy + 3 * dot
    if kind == "fcg":
        return spmv + 3 * axpy + 4 * dot
    if kind == "bicgstab":
        return 2 * spmv + 6 * axpy + 5 * dot
    if kind == "cgs":
        return 2 * spmv + 7 * axpy + 2 * dot
    if kind == "gmres":  # per inner iteration, restart 30 amortized
        return spmv + 30 * dot + 31 * axpy
    raise KeyError(kind)


def run(bandwidth: float, small: bool = False) -> None:
    bound = bandwidth / 4 / 1e9  # f32 ai=1 bound, GFLOP/s
    suite = spd_suite(small)
    stop = solvers.Stop(max_iters=ITERS, reduction_factor=0.0)  # fixed budget
    with use_executor(XlaExecutor()):
        for mat_name, a in suite.items():
            n = a.shape[0]
            nnz = int((a != 0).sum())
            A = sparse.csr_from_dense(a)
            b = jnp.asarray(np.ones(n, np.float32))
            for kind, fn in (
                ("cg", solvers.cg),
                ("fcg", solvers.fcg),
                ("bicgstab", solvers.bicgstab),
                ("cgs", solvers.cgs),
            ):
                solve = jax.jit(lambda b, fn=fn: fn(A, b, stop=stop).x)
                t = time_fn(solve, b, warmup=1, repeats=3)
                gflops = ITERS * flops_per_iter(kind, nnz, n) / t / 1e9
                emit(
                    f"solver_{kind}_{mat_name}",
                    t * 1e6,
                    f"{gflops:.3f}GFLOP/s_frac{gflops/bound:.2f}",
                )


if __name__ == "__main__":
    from benchmarks.bench_stream import run as stream_run

    bw = stream_run(sizes=(1 << 22,))
    run(bw, small=True)
