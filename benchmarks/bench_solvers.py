"""Krylov solver survey (paper Figs. 12-14): GFLOP/s vs the ai=1 bound.

The paper runs each solver 10k iterations on 10 matrices and reports
GFLOP/s against the aggressive arithmetic-intensity-1 bound (BW / bytes-per-
value: f64 -> BW/8; here f32 -> BW/4).  We run a fixed iteration budget
(restart-free stopping disabled) and count flops structurally:

    per CG iteration: 1 SpMV (2 nnz) + 3 axpy (2n) + 2 dots (2n) + norm (2n)
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, spd_suite, time_fn
from repro import solvers, sparse
from repro.core import XlaExecutor, use_executor

ITERS = 200


def flops_per_iter(kind: str, nnz: int, n: int) -> float:
    spmv = 2 * nnz
    axpy = 2 * n
    dot = 2 * n
    if kind == "cg":
        return spmv + 3 * axpy + 3 * dot
    if kind == "fcg":
        return spmv + 3 * axpy + 4 * dot
    if kind == "bicgstab":
        return 2 * spmv + 6 * axpy + 5 * dot
    if kind == "cgs":
        return 2 * spmv + 7 * axpy + 2 * dot
    if kind == "gmres":  # per inner iteration, restart 30 amortized
        return spmv + 30 * dot + 31 * axpy
    raise KeyError(kind)


def run(bandwidth: float, small: bool = False) -> None:
    bound = bandwidth / 4 / 1e9  # f32 ai=1 bound, GFLOP/s
    suite = spd_suite(small)
    stop = solvers.Stop(max_iters=ITERS, reduction_factor=0.0)  # fixed budget
    with use_executor(XlaExecutor()):
        for mat_name, a in suite.items():
            n = a.shape[0]
            nnz = int((a != 0).sum())
            A = sparse.csr_from_dense(a)
            b = jnp.asarray(np.ones(n, np.float32))
            for kind, fn in (
                ("cg", solvers.cg),
                ("fcg", solvers.fcg),
                ("bicgstab", solvers.bicgstab),
                ("cgs", solvers.cgs),
            ):
                solve = jax.jit(lambda b, fn=fn: fn(A, b, stop=stop).x)
                t = time_fn(solve, b, warmup=1, repeats=3)
                gflops = ITERS * flops_per_iter(kind, nnz, n) / t / 1e9
                emit(
                    f"solver_{kind}_{mat_name}",
                    t * 1e6,
                    f"{gflops:.3f}GFLOP/s_frac{gflops/bound:.2f}",
                )


def precond_fixture(small: bool = False):
    """Blocked SPD system with mixed per-block conditioning — the adaptive
    block-Jacobi showcase fixture (well-conditioned blocks drop to 16-bit
    storage, stretched ones stay fp32)."""
    rng = np.random.default_rng(7)
    n, bs = (512 if small else 2048), 8
    a = np.zeros((n, n), np.float32)
    for bi, s in enumerate(range(0, n, bs)):
        blk = rng.normal(size=(bs, bs)).astype(np.float32)
        blk = blk @ blk.T + 4 * np.eye(bs, dtype=np.float32)
        if bi % 3 == 0:  # every third block badly scaled
            scale = np.linspace(1.0, 30.0, bs).astype(np.float32)
            blk = blk * np.sqrt(scale[:, None] * scale[None, :])
        a[s : s + bs, s : s + bs] = blk
    for i in range(n - bs):
        a[i, i + bs] = a[i + bs, i] = 0.05
    return a, bs


def nonsym_suite(small: bool = False):
    """Nonsymmetric/realistic-spectrum gallery systems (PR-10 corpus)."""
    from repro.sparse.gallery import convection_diffusion_2d, power_law_laplacian

    side = 24 if small else 48
    n = 512 if small else 2048
    return {
        f"convdiff{side}_pe0p5": convection_diffusion_2d(
            side, peclet=0.5, scheme="centered"),
        f"convdiff{side}_pe5": convection_diffusion_2d(
            side, peclet=5.0, scheme="upwind"),
        f"powerlaw{n}": power_law_laplacian(n, seed=4),
    }


def run_nonsym(small: bool = False) -> None:
    """Nonsymmetric solver survey: time-to-tolerance for the solvers that are
    actually safe on nonsymmetric A (gmres, bicgstab, cgs) over the gallery
    corpus.  CG is deliberately absent: the symmetry guard rejects these
    operands (that rejection is pinned by the tier-1 suite, not timed here).
    """
    stop = solvers.Stop(max_iters=2000, reduction_factor=1e-6)
    with use_executor(XlaExecutor()):
        for mat_name, (indptr, indices, values, shape) in nonsym_suite(small).items():
            A = sparse.csr_from_arrays(indptr, indices, values, shape)
            rng = np.random.default_rng(0)
            b = jnp.asarray(rng.normal(size=shape[0]).astype(np.float32))
            for kind, fn in (
                ("gmres", solvers.gmres),
                ("bicgstab", solvers.bicgstab),
                ("cgs", solvers.cgs),
            ):
                res = fn(A, b, stop=stop)
                solve = jax.jit(lambda b, fn=fn: fn(A, b, stop=stop).x)
                t = time_fn(solve, b, warmup=1, repeats=3)
                emit(
                    f"nonsym_{kind}_{mat_name}",
                    t * 1e6,
                    f"iters{int(res.iterations)}_conv{int(bool(res.converged))}",
                )


def run_preconditioners(small: bool = False) -> None:
    """Preconditioner survey (the adaptive block-Jacobi feature table):
    CG iterations, wall time, and preconditioner storage per variant."""
    a, bs = precond_fixture(small)
    n = a.shape[0]
    A = sparse.csr_from_dense(a)
    rng = np.random.default_rng(0)
    xstar = rng.normal(size=n).astype(np.float32)
    b = jnp.asarray((a @ xstar).astype(np.float32))
    stop = solvers.Stop(max_iters=1000, reduction_factor=1e-6)
    with use_executor(XlaExecutor()):
        # every variant is a LinOp — the identity included — so the survey
        # reads storage_bytes off the uniform interface, no isinstance
        # checks or getattr defaults
        variants = {
            "identity": solvers.identity_preconditioner,
            "jacobi": solvers.jacobi_preconditioner(A),
            "block_jacobi_fp32": solvers.block_jacobi_preconditioner(A, block_size=bs),
            "block_jacobi_adaptive": solvers.block_jacobi_preconditioner(
                A, block_size=bs, adaptive=True
            ),
        }
        for name, M in variants.items():
            res = solvers.cg(A, b, stop=stop, M=M)
            t = time_fn(
                lambda b, M=M: solvers.cg(A, b, stop=stop, M=M).x,
                b, warmup=1, repeats=3,
            )
            detail = f"iters{int(res.iterations)}_storage{M.storage_bytes}B"
            counts = getattr(M, "precision_counts", None)
            if counts:
                detail += "_" + "+".join(f"{d}:{c}" for d, c in counts)
            emit(f"precond_cg_{name}", t * 1e6, detail)
            assert bool(res.converged), f"{name} failed to converge"


def run_ir(small: bool = False, smoke: bool = False) -> None:
    """Mixed-precision iterative refinement survey (the LinOp showcase).

    Solves the SPD suite to the f64 tolerance two ways — plain f64 CG vs an
    IR outer loop whose inner CG runs on an f32 copy of A (half the operator
    bytes per inner iteration) — and reports wall time, outer sweeps, and
    inner-operator storage.  ``smoke=True`` runs one small system and asserts
    convergence (the CI gate for the IR path).
    """
    from jax import experimental as jax_experimental

    from repro.precond import unit_roundoff

    suite = spd_suite(small or smoke)
    if smoke:
        name = "stencil2d_32"
        suite = {name: suite[name]}
    stop = solvers.Stop(max_iters=200, reduction_factor=1e-12)
    with jax_experimental.enable_x64(True), use_executor(XlaExecutor()):
        for mat_name, a in suite.items():
            a = a.astype(np.float64)
            n = a.shape[0]
            A = sparse.csr_from_dense(a)
            rng = np.random.default_rng(11)
            xstar = rng.normal(size=n)
            b = jnp.asarray(a @ xstar)

            res64 = solvers.cg(A, b, stop=stop)
            t64 = time_fn(
                lambda b: solvers.cg(A, b, stop=stop).x, b, warmup=1, repeats=3
            )
            emit(
                f"ir_cg_f64_{mat_name}", t64 * 1e6,
                f"iters{int(res64.iterations)}_storage{A.memory_bytes}B",
            )

            # generation (the astype cast + inner-solver factory) happens once,
            # outside the timer — like the f64 baseline's prebuilt A above
            A_low = A.astype(jnp.float32)
            inner = solvers.CgSolver(
                A_low,
                stop=solvers.Stop(
                    max_iters=200,
                    reduction_factor=unit_roundoff(jnp.float32) ** 0.5,
                ),
            )
            solve_ir = lambda b: solvers.ir(  # noqa: E731
                A, b, stop=stop, inner=inner, inner_dtype=jnp.float32
            )
            res_ir = solve_ir(b)
            t_ir = time_fn(lambda b: solve_ir(b).x, b, warmup=1, repeats=3)
            emit(
                f"ir_mixed_f32_{mat_name}", t_ir * 1e6,
                f"sweeps{int(res_ir.iterations)}_innerstorage{A_low.memory_bytes}B",
            )
            if smoke:
                assert bool(res_ir.converged), "mixed-precision IR failed to converge"
                err = float(jnp.abs(res_ir.x - xstar).max())
                assert err < 1e-8, f"IR error {err} above f64 tolerance"
                print(f"# ir smoke ok: {int(res_ir.iterations)} sweeps, err {err:.2e}")


if __name__ == "__main__":
    from benchmarks.bench_stream import run as stream_run

    bw = stream_run(sizes=(1 << 22,))
    run(bw, small=True)
    run_preconditioners(small=True)
    run_ir(small=True)
