"""Cooperative-groups benchmark (paper Fig. 3): repro's portable subgroup
reduce/ballot vs the direct ("vendor-native") formulation, across subgroup
sizes and dtypes.

Paper claim reproduced: the portable cooperative-group implementation is
competitive with the native one (on TPU/XLA both lower to the same vector
ops; the CPU timing here verifies no pathological overhead, and the identity
is asserted numerically in tests/core/test_coop.py).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import coop


def run(rows: int = 4096, lanes: int = 128) -> None:
    rng = np.random.default_rng(0)
    for dtype, dname in ((jnp.float32, "f32"), (jnp.bfloat16, "bf16")):
        x = jnp.asarray(rng.normal(size=(rows, lanes)), dtype)
        for size in (2, 4, 8, 16, 32):
            portable = jax.jit(
                lambda x, s=size: coop.subgroup(x, s).sum()
            )
            native = jax.jit(
                lambda x, s=size: jnp.broadcast_to(
                    x.reshape(rows, lanes // s, s).sum(-1, keepdims=True),
                    (rows, lanes // s, s),
                ).reshape(rows, lanes)
            )
            tp = time_fn(portable, x)
            tn = time_fn(native, x)
            gb = x.size * x.dtype.itemsize * 2 / 1e9
            emit(f"coop_reduce_{dname}_sg{size}", tp * 1e6,
                 f"{gb/tp:.2f}GB/s_vs_native_{gb/tn:.2f}GB/s")
    # ballot/popcount path (paper's any/all building block; dtype-independent)
    pred = jnp.asarray(rng.integers(0, 2, size=(rows, lanes)).astype(bool))
    for size in (4, 8, 16, 32):
        bal = jax.jit(
            lambda p, s=size: coop.subgroup(jnp.zeros((rows, lanes)), s).count(p)
        )
        tb = time_fn(bal, pred)
        emit(f"coop_ballot_count_sg{size}", tb * 1e6,
             f"{rows*lanes/tb/1e9:.2f}Gpred/s")


if __name__ == "__main__":
    run()
