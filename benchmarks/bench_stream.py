"""BabelStream/mixbench analogue (paper Figs. 6-8): measured machine bandwidth.

On the target TPU v5e the constants are known (819 GB/s HBM); on this CPU
container we MEASURE the attainable bandwidth, which the SpMV/solver
benchmarks then use as their roofline denominator — the same relative
methodology as the paper (kernel GFLOP/s vs stream-measured bound).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn


def run(sizes=(1 << 20, 1 << 22, 1 << 24)) -> float:
    """Returns the peak measured triad bandwidth (bytes/s)."""
    best = 0.0
    for n in sizes:
        a = jnp.arange(n, dtype=jnp.float32)
        b = jnp.ones(n, jnp.float32) * 2.0
        c = jnp.ones(n, jnp.float32) * 0.5

        copy = jax.jit(lambda a: a * 1.0)
        mul = jax.jit(lambda a: a * 3.0)
        add = jax.jit(lambda a, b: a + b)
        triad = jax.jit(lambda b, c: b + 1.5 * c)
        dot = jax.jit(lambda a, b: jnp.vdot(a, b))

        mb = n * 4 / 1e6
        for name, fn, args, streams in (
            ("copy", copy, (a,), 2),
            ("mul", mul, (a,), 2),
            ("add", add, (a, b), 3),
            ("triad", triad, (b, c), 3),
            ("dot", dot, (a, b), 2),
        ):
            t = time_fn(fn, *args)
            bw = streams * n * 4 / t
            best = max(best, bw)
            emit(f"stream_{name}_{mb:.0f}MB", t * 1e6, f"{bw/1e9:.2f}GB/s")
    return best


if __name__ == "__main__":
    run()
