"""mixbench analogue (paper Figs. 6-8 bottom row): arithmetic-intensity sweep.

Measures GFLOP/s of y = poly_k(x) kernels with k fused multiply-adds per
element — as k grows the kernel crosses from bandwidth-bound to compute-bound,
tracing the machine's roofline knee (the paper uses mixbench to place each
GPU's knee; the SpMV/solver fractions are then read against the flat part).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn


def run(n: int = 1 << 22) -> None:
    x = jnp.linspace(0.0, 1.0, n, dtype=jnp.float32)
    for k in (1, 2, 4, 8, 16, 32, 64):
        def kernel(x, k=k):
            acc = x
            for i in range(k):
                acc = acc * 1.000001 + 0.5  # k FMAs per element
            return acc

        fn = jax.jit(kernel)
        t = time_fn(fn, x)
        flops = 2 * k * n / t
        bw = 2 * n * 4 / t
        emit(f"mixbench_fma{k}", t * 1e6,
             f"{flops/1e9:.2f}GFLOP/s_{bw/1e9:.2f}GB/s_ai{k/4:.2f}")


if __name__ == "__main__":
    run()
