"""Benchmark harness entry point: one function per paper table/figure.

Emits ``name,us_per_call,derived`` CSV lines:

  Fig. 3   -> bench_coop      (portable cooperative groups vs native)
  Figs 6-8 -> bench_stream    (machine bandwidth; roofline denominator)
  Figs 9-11-> bench_spmv      (SpMV survey: formats x matrices, frac-of-bound)
  Figs12-14-> bench_solvers   (Krylov solvers, frac-of-ai=1-bound)
  Roofline -> roofline        (LM cells from the dry-run artifacts, if present)

Run: PYTHONPATH=src python -m benchmarks.run [--full]
     PYTHONPATH=src python -m benchmarks.run --smoke
     PYTHONPATH=src python -m benchmarks.run --autotune [--target NAME] [--out PATH]
     PYTHONPATH=src python -m benchmarks.run --bench-json BENCH_pr6.json

``--smoke`` is the CI gate: one batched solve plus one mixed-precision IR
solve end to end (asserting convergence), fast enough for every PR —
kernel-launch and solver regressions surface before merge instead of in the
nightly figures.

``--autotune`` runs the launch-configuration sweep instead of the paper
figures: it measures candidate tile geometries per op (benchmarks/autotune.py)
and persists the winners as a per-target tuning table consumable by
``repro.core.tuning.load_table`` / the ``REPRO_TUNING_PATH`` env var.

``--bench-json PATH`` writes the schema'd BENCH snapshot (benchmarks/report.py)
instead of CSV: fused-vs-plain SpMV frac-of-bound, solver time-to-tolerance,
launch/collective structure pins — the artifact the regression gate
(benchmarks/check_regression.py) diffs across PRs.
"""

from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full-size matrices (slower; default: small suite)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: one batched solve + one mixed-precision "
                         "IR solve end to end, assertive")
    ap.add_argument("--autotune", action="store_true",
                    help="sweep candidate kernel tilings and persist the "
                         "winners as a per-target tuning table")
    ap.add_argument("--target", default="cpu_interpret",
                    help="hardware target for --autotune "
                         "(see repro.core.params.TARGETS)")
    ap.add_argument("--out", default=None,
                    help="tuning-table output path for --autotune")
    ap.add_argument("--bench-json", default=None, metavar="PATH",
                    help="write the schema'd BENCH snapshot (JSON) instead "
                         "of the CSV figures")
    ap.add_argument("--metrics-jsonl", default=None, metavar="PATH",
                    help="with --bench-json: also export the live metrics "
                         "registry (achieved GB/s, frac-of-bound gauges) "
                         "as JSONL")
    args = ap.parse_args()
    small = not args.full

    if args.bench_json:
        from benchmarks import report

        report.write(args.bench_json)
        if args.metrics_jsonl:
            from repro.observability import metrics

            metrics.export_jsonl(args.metrics_jsonl)
            print(f"# metrics -> {args.metrics_jsonl}")
        return

    if args.autotune:
        from benchmarks import autotune

        autotune.run(target=args.target, out=args.out)
        return

    if args.smoke:
        from benchmarks import bench_batch, bench_solvers

        print("# batched-solve smoke (asserts convergence)")
        bench_batch.run(smoke=True)
        print("# mixed-precision IR smoke (asserts f64-tolerance convergence)")
        bench_solvers.run_ir(smoke=True)
        return

    from benchmarks import bench_coop, bench_solvers, bench_spmv, bench_stream

    print("# coop groups (paper Fig. 3)")
    bench_coop.run()

    print("# mixbench arithmetic-intensity sweep (paper Figs. 6-8, bottom)")
    from benchmarks import bench_mixbench

    bench_mixbench.run()

    print("# stream bandwidth (paper Figs. 6-8)")
    bw = bench_stream.run(
        sizes=(1 << 22, 1 << 24) if small else (1 << 22, 1 << 24, 1 << 26)
    )

    print(f"# spmv survey (paper Figs. 9-11), bound from measured {bw/1e9:.1f} GB/s")
    bench_spmv.run(bw, small=small)

    print("# krylov solvers (paper Figs. 12-14)")
    bench_solvers.run(bw, small=small)

    print("# nonsymmetric gallery corpus (gmres / bicgstab / cgs)")
    bench_solvers.run_nonsym(small=small)

    print("# preconditioner survey (adaptive-precision block-Jacobi)")
    bench_solvers.run_preconditioners(small=small)

    print("# mixed-precision iterative refinement (f32 inner CG, f64 outer)")
    bench_solvers.run_ir(small=small)

    print("# batched solves (one launch vs a loop of single solves)")
    from benchmarks import bench_batch

    bench_batch.run(small=small)

    # LM roofline cells (only if the dry-run artifacts exist)
    try:
        from benchmarks import roofline

        cells = roofline.load_cells()
        if cells:
            print("# LM roofline cells (from dry-run artifacts)")
            roofline.csv(cells)
    except Exception as e:  # noqa: BLE001
        print(f"# roofline cells unavailable: {e}")


if __name__ == "__main__":
    main()
