"""Roofline table generator: reads experiments/dryrun/*.json (written by
``python -m repro.launch.dryrun``) and emits the §Roofline table for
EXPERIMENTS.md — per (arch x shape x mesh): the three terms, the bottleneck,
and MODEL_FLOPS/HLO_FLOPS (useful fraction)."""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load_cells(pattern: str = "*.json") -> List[Dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, pattern))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def fmt_ms(s: float) -> str:
    return f"{s*1e3:.2f}"


def advice(c: Dict) -> str:
    """One sentence: what moves this cell's dominant term down (assignment g)."""
    b = c["roofline"]["bottleneck"]
    arch, shape = c["arch"], c["shape"]
    moe = arch.startswith(("qwen2", "olmoe"))
    ssm = arch.startswith(("rwkv6", "zamba2"))
    if b == "collective":
        if ssm and "decode" in shape or shape == "long_500k":
            return ("state all-gathers from non-divisible head counts: pad heads "
                    "to the model axis or replicate state per column")
        if moe:
            return "a2a expert dispatch + FSDP (measured -40% in §Perf cell B)"
        return "overlap grad all-reduce with bwd compute (ring matmul / async)"
    if b == "memory":
        if shape in ("prefill_32k", "train_4k") and not ssm:
            return ("flash kernel contract removes the S x Skv score traffic "
                    "(175x on minicpm3 prefill, §Perf cell C)")
        if "decode" in shape:
            return ("cache reads are the floor: quantize KV to int8 or shrink "
                    "kv heads/latents (MLA already 18x smaller than GQA here)"
                    if arch != "minicpm3_4b" else
                    "latent cache already minimal; batch more requests per step")
        if ssm:
            return ("chunked-scan carries dominate: fuse the chunk pipeline in "
                    "the Pallas kernel (state stays in VMEM across chunks)")
        return "dots-remat policy + flash-VJP kernel cut recompute traffic"
    return ("compute-bound: raise MXU utilization (bf16 tiles aligned, larger "
            "per-chip batch) or accept — this is the roofline")


def table(cells: List[Dict], markdown: bool = True) -> str:
    rows = []
    header = (
        "| arch | shape | mesh | compute ms | memory ms | collective ms | "
        "bottleneck | useful frac | peak GiB/dev | to move the dominant term |"
    )
    sep = "|" + "---|" * 10
    for c in cells:
        r = c["roofline"]
        mf = c["model_flops"]
        peak = c["memory_analysis"].get("peak_bytes")
        peak_s = f"{peak/2**30:.2f}" if peak else "-"
        uf = mf.get("useful_fraction")
        rows.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} | "
            f"{fmt_ms(r['compute_s'])} | {fmt_ms(r['memory_s'])} | "
            f"{fmt_ms(r['collective_s'])} | {r['bottleneck']} | "
            f"{uf:.3f} | {peak_s} | {advice(c)} |"
        )
    return "\n".join([header, sep] + rows)


def csv(cells: List[Dict]) -> None:
    for c in cells:
        r = c["roofline"]
        name = f"roofline_{c['arch']}_{c['shape']}_{c['mesh']}"
        total = max(r["compute_s"], r["memory_s"], r["collective_s"])
        frac = r["compute_s"] / total if total else 0.0
        print(
            f"{name},{total*1e6:.1f},"
            f"bottleneck={r['bottleneck']}_computefrac{frac:.2f}"
            f"_useful{c['model_flops']['useful_fraction']:.3f}"
        )


if __name__ == "__main__":
    cells = load_cells()
    print(table(cells))
